"""Deterministic synthetic LM data pipeline.

Requirements it satisfies (DESIGN.md §7):
  * shardable   — each (host, step) pair derives its batch shard from a
    counter-based PRNG (threefry fold-in of step & host), so any number of
    hosts produce disjoint, reproducible data with NO coordination;
  * checkpointable — iterator state is just {step}; restoring a checkpoint
    replays the exact token stream from that step;
  * elastic     — resharding to a different host count only changes which
    host materializes which rows, not the global batch content (the global
    batch for step s is a pure function of (seed, s)).

The synthetic distribution is a order-0 Markov stream with a
position-dependent bias — enough structure that a ~100M model's loss
visibly drops (examples/train_mla.py) while needing no external corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


@dataclasses.dataclass
class DataState:
    step: int = 0


def _row_at(seed: int, step: int, row: jax.Array, L: int, V: int) -> jax.Array:
    """One global row (seq_len+1,) — pure fn of (seed, step, global row id).

    Learnable structure: a mixture of a NARROW unigram (75% of tokens from
    the first min(32, V//4) ids) and a uniform tail — cross entropy drops
    from ln(V) toward the mixture entropy (~1 nat of headroom) within tens
    of steps for any architecture (the unigram is learnable by the output
    bias/embedding alone), which the examples/tests assert."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    key = jax.random.fold_in(key, row)
    k1, k2, k3 = jax.random.split(key, 3)
    narrow_v = max(2, min(32, V // 4))
    base = jax.random.randint(k1, (L,), 0, V, dtype=jnp.int32)
    narrow = jax.random.randint(k3, (L,), 0, narrow_v, dtype=jnp.int32)
    gate = jax.random.uniform(k2, (L,)) < 0.75
    return jnp.where(gate, narrow, base)


def _batch_at(cfg: DataConfig, step: int, host_id: Optional[int] = None) -> np.ndarray:
    """Tokens (local_batch, seq_len+1) for this host at ``step``.

    Rows are keyed by *global* row id, so the global batch content is
    invariant to the host count (elastic resharding changes only which
    host materializes which rows)."""
    host = cfg.host_id if host_id is None else host_id
    rows = jnp.arange(cfg.local_batch, dtype=jnp.int32) + host * cfg.local_batch
    L, V = cfg.seq_len + 1, cfg.vocab
    toks = jax.vmap(lambda r: _row_at(cfg.seed, step, r, L, V))(rows)
    return np.asarray(toks)


class SyntheticLM:
    """Iterator with explicit, restorable state."""

    def __init__(self, cfg: DataConfig, state: Optional[DataState] = None):
        self.cfg = cfg
        self.state = state or DataState()

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (B, L), labels (B, L)) and advances the state."""
        toks = _batch_at(self.cfg, self.state.step)
        self.state = DataState(self.state.step + 1)
        return toks[:, :-1], toks[:, 1:]

    def peek_global(self, step: int) -> np.ndarray:
        """Full global batch at a step (tests: shard-invariance)."""
        return np.concatenate(
            [_batch_at(dataclasses.replace(self.cfg, host_id=h), step)
             for h in range(self.cfg.n_hosts)], axis=0)

    # ---- checkpoint integration ----------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.state.step}

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState(int(d["step"]))
