"""Multi-Head Latent Attention (DeepSeek-V2/V3) with the paper's execution
schemes as a first-class, runtime-selectable feature.

Decode score chain (paper notation, per head):

    z = q_l . W_up^Q . W_up^{K,T} . C^T          (+ decoupled RoPE path)

Execution schemes (``scheme=`` argument of :func:`mla_decode`):

  'naive'  1->3->2 : up-project the whole latent cache to K/V, run MHA.
           Cost: O(L * D_kvl * (D_qk + D_v)) extra FLOPs per step. Paper's
           strawman; implemented for fidelity + as a numerics oracle.
  'seq'    1->2->3 : q_l -> (W_up^Q) -> q -> (W_up^{K,T}) -> latent space.
           Fewest FLOPs (D_ql*D_qk + D_qk*D_kvl MACs/head) at the same
           weight bytes as 'rc'.  *Beyond-paper deployment default* — see
           DESIGN.md: strictly dominates rc/ru on a two-term roofline.
  'rc'     2->1->3 : recompute W_absorb = W_up^Q @ W_up^{K,T} every step,
           keep it on-chip (paper's MLA_rc).  +D_ql*D_qk*D_kvl MACs/head,
           but only the small factors are read from HBM -> highest OI.
  'ru'     1->2->3 on precomputed W_absorb streamed from HBM (paper's
           MLA_ru). Fewest marginal FLOPs but D_ql*D_kvl weight words/head.

All schemes compute the *same function with identical weights* (paper:
"the choice between them can be made dynamically").  ``tests/test_mla.py``
asserts allclose-equivalence across schemes, prefill vs decode.

Output chain ``y = s . C . W_up^V . W^O`` is executed left-to-right in
decode ((s@C)@W_uv@W_o — FLOP- and byte-optimal; see DESIGN.md note on the
paper's "right-to-left" remark which applies to the prefill phase where the
score matrix is L x L and V must be materialized first).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..nn import layers as nl
from ..nn.module import P
from . import cache as cachelib
from .attention import NEG_INF, gqa_attention

SCHEMES = ("naive", "seq", "rc", "ru")


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    @property
    def cache_dims(self) -> Tuple[int, int]:
        return self.kv_lora_rank, self.qk_rope_dim


def mla_defs(cfg: MLAConfig) -> Dict[str, Any]:
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        # q path: down -> norm -> up (nope+rope per head)
        "w_dq": P((cfg.d_model, cfg.q_lora_rank), ("embed", "q_lora")),
        "q_norm": nl.rmsnorm_defs(cfg.q_lora_rank, "q_lora"),
        "w_uq": P((cfg.q_lora_rank, H, dn + dr), ("q_lora", "heads", None)),
        # kv path: joint down-projection -> [latent | shared rope key]
        "w_dkv": P((cfg.d_model, cfg.kv_lora_rank + dr), ("embed", None)),
        "kv_norm": nl.rmsnorm_defs(cfg.kv_lora_rank, "kv_lora"),
        "w_uk": P((cfg.kv_lora_rank, H, dn), ("kv_lora", "heads", None)),
        "w_uv": P((cfg.kv_lora_rank, H, dv), ("kv_lora", "heads", None)),
        "w_o": P((H, dv, cfg.d_model), ("heads", None, "embed")),
    }


def param_count(cfg: MLAConfig, rope: bool = True) -> int:
    """Closed-form #params of one MLA layer (paper Table 1 when rope=False)."""
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, (cfg.qk_rope_dim if rope else 0), cfg.v_head_dim
    return (cfg.d_model * cfg.q_lora_rank
            + cfg.q_lora_rank * H * (dn + dr)
            + cfg.d_model * (cfg.kv_lora_rank + dr)
            + cfg.kv_lora_rank * H * (dn + dv)
            + H * dv * cfg.d_model)


def absorb_qk(params: Dict[str, Any], cfg: MLAConfig):
    """W_absorb = W_up^Q(nope) @ W_up^{K,T} : (H, D_ql, D_kvl).

    'ru' precomputes this once at engine build; 'rc' recomputes per step."""
    w_uq_nope = params["w_uq"][:, :, : cfg.qk_nope_dim]  # (Q, H, dn)
    return jnp.einsum("qhn,khn->hqk", w_uq_nope.astype(jnp.float32),
                      params["w_uk"].astype(jnp.float32))


def prepare_serving(params: Dict[str, Any], cfg: MLAConfig, scheme: str) -> Dict[str, Any]:
    """Engine-build step: attach precomputed absorbed weights for 'ru'."""
    if scheme == "ru":
        params = dict(params)
        params["w_absorb"] = absorb_qk(params, cfg).astype(params["w_uq"].dtype)
    return params


def attach_absorbed_tree(params, cfg: MLAConfig):
    """Walk a full model param tree and attach precomputed W_absorb on
    every MLA sublayer (stacked scan layers get a vmapped absorb).  'ru'
    streams the extra leaf; other schemes ignore it, so one prepared tree
    serves every runtime-dispatched scheme."""
    def visit(node):
        if isinstance(node, dict):
            if "w_uq" in node and "w_uk" in node:
                w_uq = node["w_uq"]
                if w_uq.ndim == 4:       # stacked (layers, Q, H, d)
                    absorb = jax.vmap(
                        lambda q, k: absorb_qk({"w_uq": q, "w_uk": k},
                                               cfg))(w_uq, node["w_uk"])
                else:
                    absorb = absorb_qk(node, cfg)
                return {**node, "w_absorb": absorb.astype(w_uq.dtype)}
            return {k: visit(v) for k, v in node.items()}
        return node

    return visit(params)


# ------------------------------------------------------------- projections -


def _q_proj(params, cfg: MLAConfig, x, positions):
    """x: (B, L, D) -> q_l (B,L,Q), q_nope (B,L,H,dn), q_rope (B,L,H,dr)."""
    q_l = nl.rmsnorm(params["q_norm"], x @ params["w_dq"].astype(x.dtype))
    q = jnp.einsum("blq,qhd->blhd", q_l, params["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = nl.apply_rope(q_rope, positions, cfg.rope_base)
    return q_l, q_nope, q_rope


def _kv_latent(params, cfg: MLAConfig, x, positions):
    """x: (B, L, D) -> ckv (B,L,Dkvl) normalized, krope (B,L,dr) rotated."""
    c = x @ params["w_dkv"].astype(x.dtype)
    ckv = nl.rmsnorm(params["kv_norm"], c[..., : cfg.kv_lora_rank])
    krope = nl.apply_rope(c[..., cfg.kv_lora_rank:], positions, cfg.rope_base)
    return ckv, krope


# ----------------------------------------------------------------- prefill -


def mla_prefill(params, cfg: MLAConfig, x, positions, *, attn_fn=None,
                return_cache: bool = True):
    """Training / prefill forward ("MHA mode": materialize K, V per head).

    x: (B, L, D). Returns (out (B,L,D), cache_entries or None).
    The paper's "right-to-left" output ordering = compute V first, standard
    attention in the full space — optimal when scores are L x L.
    """
    _, q_nope, q_rope = _q_proj(params, cfg, x, positions)
    ckv, krope = _kv_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("blk,khn->blhn", ckv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("blk,khv->blhv", ckv, params["w_uv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    scale = cfg.qk_dim ** -0.5
    if attn_fn is None:
        o = gqa_attention(q, k, v, causal=True, q_positions=positions[0],
                          k_positions=positions[0], softmax_scale=scale)
    else:
        o = attn_fn(q, k, v, softmax_scale=scale)
    out = jnp.einsum("blhv,hvd->bld", o, params["w_o"].astype(x.dtype))
    entries = {"ckv": ckv, "krope": krope} if return_cache else None
    return out, entries


def _q_latent_chunk(params, cfg: MLAConfig, q_l, q_nope, scheme: str):
    """Chunk-shaped sibling of :func:`_q_latent`: map a (B, C, ...) chunk
    of nope-queries into the KV-latent space per execution scheme.
    Returns q_eff: (B, C, H, D_kvl)."""
    if scheme == "seq":
        return jnp.einsum("bchn,khn->bchk", q_nope,
                          params["w_uk"].astype(q_nope.dtype))
    if scheme == "rc":
        w_absorb = jnp.einsum(
            "qhn,khn->hqk",
            params["w_uq"][:, :, : cfg.qk_nope_dim].astype(jnp.float32),
            params["w_uk"].astype(jnp.float32)).astype(q_l.dtype)
        return jnp.einsum("bcq,hqk->bchk", q_l, w_absorb)
    if scheme == "ru":
        return jnp.einsum("bcq,hqk->bchk", q_l,
                          params["w_absorb"].astype(q_l.dtype))
    raise ValueError(f"unknown scheme {scheme}")


def mla_prefill_chunk_paged(params, cfg: MLAConfig, x, pool: Dict[str, Any],
                            block_table, lengths, n_valid, *,
                            scheme: str = "seq", impl: str = "gather",
                            prefill_kernel=None):
    """One CHUNK of batched prefill, directly into the paged pool.

    x: (B, C, D) — row b carries the next ``n_valid[b]`` prompt tokens of
    its request, starting at absolute position ``lengths[b]`` (tokens
    already in the pool: the prefix-cache hit plus earlier chunks).
    Rows with ``n_valid[b] == 0`` are idle padding (their output is
    garbage the engine discards; their latents scatter to the null
    block).  Returns (out (B, C, D), new_pool).

    This is ALSO the speculative-decode verify forward (models.lm
    .verify_chunk_paged / runtime.steps.make_verify_step): with C = k + 1
    the chunk is [last sampled token, k drafts] and each request's
    resident latent prefix streams once for all k + 1 query positions —
    the cache-read amortization hwmodel.attention_costs.mla_verify_cost
    prices.  Nothing changes here: multi-query paged attention over the
    block table is the same problem whether the C tokens are prompt
    suffix or draft window.

    The chunk's latents are scattered FIRST, then the queries attend the
    resident prefix THROUGH the block table — shared prefix blocks,
    earlier chunks and the in-chunk causal triangle all ride the same
    paged path.  The nope-scores run in the latent space (q mapped
    through the scheme's absorption — 'seq'/'rc'/'ru', exactly the
    decode schemes generalized to C query positions; 'naive' up-projects
    the gathered cache, the paper's strawman), so the cached prefix is
    never up-projected to per-head K/V — same function as the "MHA-mode"
    :func:`mla_prefill` (two-term scores are an exact reordering of the
    concatenated dot product), asserted allclose in
    tests/test_prefix_cache.py and tests/test_prefill_kernel.py.

    ``impl``: 'gather' materializes the contiguous (B, S) block-table
    view (the reference path — what the roofline charges for); 'pallas'
    runs the fused paged Pallas kernel (kernels.mla_prefill) which walks
    the block table in place, no gather ever hitting HBM.  'naive' has
    no kernel path and falls back to the gather view.
    ``prefill_kernel``: optional kernel closure (models.blocks injects
    the mesh-aware ops wrapper); defaults to the unsharded kernel.
    """
    if impl not in ("gather", "pallas"):
        raise ValueError(f"unknown prefill impl {impl!r}")
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    B, C, _ = x.shape
    pos = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    q_l, q_nope, q_rope = _q_proj(params, cfg, x, pos)
    ckv_new, krope_new = _kv_latent(params, cfg, x, pos)
    pool = cachelib.update_latent_paged_chunk(pool, block_table, lengths,
                                              n_valid, ckv_new, krope_new)
    scale = cfg.qk_dim ** -0.5

    if impl == "pallas" and scheme != "naive":
        # the deployment path: the kernel walks the block table in place —
        # no contiguous gather is ever materialized (ROADMAP: the last
        # HBM-materializing hot path in the serving engine).
        q_eff = _q_latent_chunk(params, cfg, q_l, q_nope, scheme)
        q_full = jnp.concatenate([q_eff, q_rope], axis=-1)
        if prefill_kernel is None:
            from ..kernels import ops as kops  # local import: no cycle
            prefill_kernel = functools.partial(
                kops.mla_prefill_paged_attention, impl="kernel")
        qkw = {}
        if cachelib.is_quantized_pool(pool):
            # quantized pool: ship the per-token-slot scales to the kernel
            # so dequant happens in-register, never in HBM
            qkw = dict(ckv_scales=pool["ckv_scale"],
                       krope_scales=pool["krope_scale"])
        o_lat = prefill_kernel(q_full, pool["ckv"], pool["krope"],
                               block_table, lengths, n_valid,
                               softmax_scale=scale, **qkw)
        o = jnp.einsum("bchk,khv->bchv", o_lat.astype(x.dtype),
                       params["w_uv"].astype(x.dtype))
        out = jnp.einsum("bchv,hvd->bcd", o, params["w_o"].astype(x.dtype))
        return out, pool

    # reference path: gather each request's pages into a contiguous view
    # (numerics oracle; materializes the (B, S) block-table view in HBM).
    ckv_c, krope_c = cachelib.gather_latent_paged(pool, block_table)
    S = ckv_c.shape[1]
    # causal over absolute positions, clipped to each request's valid
    # extent (garbage in the partial tail block / idle rows stays masked)
    s_pos = jnp.arange(S, dtype=jnp.int32)
    valid = (s_pos[None, None, :] <= pos[:, :, None]) \
        & (s_pos[None, None, :] < (lengths + n_valid)[:, None, None])
    if scheme == "naive":
        # 1->3->2: up-project the entire gathered cache (the strawman).
        k_nope = jnp.einsum("bsk,khn->bshn", ckv_c,
                            params["w_uk"].astype(ckv_c.dtype))
        v_full = jnp.einsum("bsk,khv->bshv", ckv_c,
                            params["w_uv"].astype(ckv_c.dtype))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                krope_c[:, :, None, :].astype(k_nope.dtype),
                k_nope.shape[:3] + (cfg.qk_rope_dim,))], axis=-1)
        scores = jnp.einsum("bchd,bshd->bchs", q.astype(k.dtype), k,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bchs,bshv->bchv", p.astype(v_full.dtype), v_full,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        # latent-space queries (see mla_decode's dtype NOTE: native-dtype
        # contractions with f32 accumulation — no f32 cache copy in HBM)
        q_eff = _q_latent_chunk(params, cfg, q_l, q_nope, scheme)
        scores = (jnp.einsum("bchk,bsk->bchs", q_eff.astype(ckv_c.dtype),
                             ckv_c, preferred_element_type=jnp.float32)
                  + jnp.einsum("bchr,bsr->bchs", q_rope.astype(krope_c.dtype),
                               krope_c, preferred_element_type=jnp.float32)
                  ) * scale
        scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bchs,bsk->bchk", p.astype(ckv_c.dtype), ckv_c,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        o = jnp.einsum("bchk,khv->bchv", o_lat,
                       params["w_uv"].astype(x.dtype))
    out = jnp.einsum("bchv,hvd->bcd", o, params["w_o"].astype(x.dtype))
    return out, pool


# ------------------------------------------------------------------ decode -


def _q_latent(params, cfg: MLAConfig, q_l, q_nope, scheme: str):
    """Map the nope-query into the KV-latent space per execution scheme.
    Returns q_eff: (B, H, D_kvl) (single-token decode: L dim squeezed)."""
    if scheme == "seq":
        # 1->2->3: q_nope @ W_uk^T, factored — fewest FLOPs.
        return jnp.einsum("bhn,khn->bhk", q_nope, params["w_uk"].astype(q_nope.dtype))
    if scheme == "rc":
        # 2->1->3: recompute the absorbed matrix on the fly (stays in VMEM /
        # fused by XLA — never written to HBM).
        w_absorb = jnp.einsum("qhn,khn->hqk",
                              params["w_uq"][:, :, : cfg.qk_nope_dim].astype(jnp.float32),
                              params["w_uk"].astype(jnp.float32)).astype(q_l.dtype)
        return jnp.einsum("bq,hqk->bhk", q_l, w_absorb)
    if scheme == "ru":
        return jnp.einsum("bq,hqk->bhk", q_l, params["w_absorb"].astype(q_l.dtype))
    raise ValueError(f"unknown scheme {scheme}")


def mla_decode(params, cfg: MLAConfig, x_t, cache: Dict[str, Any], index,
               *, scheme: str = "seq", decode_kernel=None):
    """One-token decode. x_t: (B, D). cache: latent cache dict (B, S, .).
    ``index``: number of tokens already cached (new token written there).

    Returns (out (B, D), new_cache).
    """
    B = x_t.shape[0]
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    x = x_t[:, None, :]
    q_l, q_nope, q_rope = _q_proj(params, cfg, x, pos)
    q_l, q_nope, q_rope = q_l[:, 0], q_nope[:, 0], q_rope[:, 0]
    ckv_new, krope_new = _kv_latent(params, cfg, x, pos)
    cache = cachelib.update_latent(cache, ckv_new, krope_new, index)
    ckv_c, krope_c = cache["ckv"], cache["krope"]   # (B,S,Dl), (B,S,Dr)
    S = ckv_c.shape[1]
    scale = cfg.qk_dim ** -0.5

    # NOTE on dtypes: all cache-wide contractions run with NATIVE-dtype
    # operands and ``preferred_element_type=f32`` (MXU semantics: bf16 in,
    # fp32 accumulate).  An ``astype(f32)`` on the cache here would be
    # hoisted out of the layer scan by XLA and materialize an f32 copy of
    # the ENTIRE stacked cache in HBM (observed: +35 GB/chip at the
    # deepseek-v2 decode_32k cell) — see EXPERIMENTS.md §Perf iteration 0.
    if scheme == "naive":
        # 1->3->2: up-project the entire cache (paper's strawman).
        k_nope = jnp.einsum("bsk,khn->bshn", ckv_c, params["w_uk"].astype(ckv_c.dtype))
        v_full = jnp.einsum("bsk,khv->bshv", ckv_c, params["w_uv"].astype(ckv_c.dtype))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_c[:, :, None, :].astype(k_nope.dtype),
                                      k_nope.shape[:3] + (cfg.qk_rope_dim,))], axis=-1)
        scores = jnp.einsum("bhd,bshd->bhs", q.astype(k.dtype), k,
                            preferred_element_type=jnp.float32) * scale
        valid = jnp.arange(S) <= index
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhs,bshv->bhv", p.astype(v_full.dtype), v_full,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        q_eff = _q_latent(params, cfg, q_l, q_nope, scheme)  # (B, H, Dkvl)
        if decode_kernel is not None:
            q_full = jnp.concatenate([q_eff, q_rope], axis=-1)
            o_lat = decode_kernel(q_full, ckv_c, krope_c, index,
                                  softmax_scale=scale)
        else:
            # MQA-style attention in the latent space (head-shared K=V).
            # Two-term scores: no q concat, no cache slice for the PV
            # contraction (the split-cache layout, §Perf A3).
            scores = (jnp.einsum("bhk,bsk->bhs", q_eff.astype(ckv_c.dtype),
                                 ckv_c, preferred_element_type=jnp.float32)
                      + jnp.einsum("bhr,bsr->bhs", q_rope.astype(krope_c.dtype),
                                   krope_c, preferred_element_type=jnp.float32)
                      ) * scale
            valid = jnp.arange(S) <= index
            scores = jnp.where(valid[None, None], scores, NEG_INF)
            p = jax.nn.softmax(scores, axis=-1)
            o_lat = jnp.einsum("bhs,bsk->bhk", p.astype(ckv_c.dtype), ckv_c,
                               preferred_element_type=jnp.float32).astype(x_t.dtype)
        # output chain left-to-right: (s@C) @ W_uv @ W_o
        o = jnp.einsum("bhk,khv->bhv", o_lat, params["w_uv"].astype(x_t.dtype))

    out = jnp.einsum("bhv,hvd->bd", o, params["w_o"].astype(x_t.dtype))
    return out, cache


def mla_decode_paged(params, cfg: MLAConfig, x_t, pool: Dict[str, Any],
                     block_table, lengths, *, scheme: str = "seq",
                     decode_kernel=None):
    """One continuous-batching decode step over the PAGED latent cache.

    x_t: (B, D) — one token per batch slot; pool: paged latent pool
    ({ckv (N,bs,Dl), krope (N,bs,Dr)}, block 0 = null); block_table:
    (B, nb) int32; lengths: (B,) int32 — tokens already cached per slot
    (ragged!).  The new token is written at position lengths[b], then each
    request attends its own 0..lengths[b] prefix.  Inactive slots (length
    0 pointing at the null block) produce garbage that the runtime
    discards.

    Returns (out (B, D), new_pool).  Same function as :func:`mla_decode`
    per request — tests/test_paged.py asserts allclose against per-request
    contiguous decode for every scheme.
    """
    lengths = jnp.asarray(lengths, jnp.int32)
    pos = lengths[:, None]                        # per-request positions
    x = x_t[:, None, :]
    q_l, q_nope, q_rope = _q_proj(params, cfg, x, pos)
    q_l, q_nope, q_rope = q_l[:, 0], q_nope[:, 0], q_rope[:, 0]
    ckv_new, krope_new = _kv_latent(params, cfg, x, pos)
    pool = cachelib.update_latent_paged(pool, block_table, lengths,
                                        ckv_new[:, 0], krope_new[:, 0])
    scale = cfg.qk_dim ** -0.5

    if scheme != "naive" and decode_kernel is not None:
        # the deployment path: the kernel walks the block table in place —
        # no contiguous gather is ever materialized.
        q_eff = _q_latent(params, cfg, q_l, q_nope, scheme)
        q_full = jnp.concatenate([q_eff, q_rope], axis=-1)
        qkw = {}
        if cachelib.is_quantized_pool(pool):
            # quantized pool: ship the per-token-slot scales to the kernel
            # so dequant happens in-register, never in HBM
            qkw = dict(ckv_scales=pool["ckv_scale"],
                       krope_scales=pool["krope_scale"])
        o_lat = decode_kernel(q_full, pool["ckv"], pool["krope"],
                              block_table, lengths, softmax_scale=scale,
                              **qkw)
        o = jnp.einsum("bhk,khv->bhv", o_lat, params["w_uv"].astype(x_t.dtype))
        out = jnp.einsum("bhv,hvd->bd", o, params["w_o"].astype(x_t.dtype))
        return out, pool

    # reference/naive paths: gather each request's pages into a contiguous
    # view (numerics oracle — same math as mla_decode with a ragged mask).
    ckv_c, krope_c = cachelib.gather_latent_paged(pool, block_table)
    S = ckv_c.shape[1]
    valid = cachelib.paged_valid_mask(S, lengths)[:, None]   # (B, 1, S)
    if scheme == "naive":
        k_nope = jnp.einsum("bsk,khn->bshn", ckv_c, params["w_uk"].astype(ckv_c.dtype))
        v_full = jnp.einsum("bsk,khv->bshv", ckv_c, params["w_uv"].astype(ckv_c.dtype))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_c[:, :, None, :].astype(k_nope.dtype),
                                      k_nope.shape[:3] + (cfg.qk_rope_dim,))], axis=-1)
        scores = jnp.einsum("bhd,bshd->bhs", q.astype(k.dtype), k,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(valid, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhs,bshv->bhv", p.astype(v_full.dtype), v_full,
                       preferred_element_type=jnp.float32).astype(x_t.dtype)
    else:
        q_eff = _q_latent(params, cfg, q_l, q_nope, scheme)
        scores = (jnp.einsum("bhk,bsk->bhs", q_eff.astype(ckv_c.dtype),
                             ckv_c, preferred_element_type=jnp.float32)
                  + jnp.einsum("bhr,bsr->bhs", q_rope.astype(krope_c.dtype),
                               krope_c, preferred_element_type=jnp.float32)
                  ) * scale
        scores = jnp.where(valid, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhs,bsk->bhk", p.astype(ckv_c.dtype), ckv_c,
                           preferred_element_type=jnp.float32).astype(x_t.dtype)
        o = jnp.einsum("bhk,khv->bhv", o_lat, params["w_uv"].astype(x_t.dtype))

    out = jnp.einsum("bhv,hvd->bd", o, params["w_o"].astype(x_t.dtype))
    return out, pool
