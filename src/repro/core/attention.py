"""Standard MHA/GQA/MQA attention — the paper's baseline family (MHA_l/MHA_s).

Pure-jnp reference implementations; models may swap in the Pallas flash
kernel (repro.kernels) for the prefill/train path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # large-but-finite: avoids NaN from all-masked rows


def _sliding_window_mask(q_pos, k_pos, window: Optional[int]):
    m = k_pos[None, :] <= q_pos[:, None]  # causal
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def gqa_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                  q_positions=None, k_positions=None, softmax_scale: Optional[float] = None):
    """q: (B, Lq, H, Dh); k,v: (B, Lk, Hkv, Dh). H % Hkv == 0.

    Returns (B, Lq, H, Dv). fp32 softmax; bf16-safe.
    """
    B, Lq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Lq, Hkv, G, Dh)
    # native-dtype operands, fp32 accumulation (MXU semantics; avoids
    # materializing f32 copies of K/V — see core/mla.py dtype note).
    scores = jnp.einsum("blhgd,bshd->bhgls", qg.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) * scale
    if causal or window is not None:
        q_pos = q_positions if q_positions is not None else jnp.arange(Lq)
        k_pos = k_positions if k_positions is not None else jnp.arange(k.shape[1])
        mask = _sliding_window_mask(q_pos, k_pos, window)  # (Lq, Lk)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgls,bshd->blhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Lq, H, v.shape[-1]).astype(q.dtype)


def gqa_decode(q, k_cache, v_cache, index, *, window: Optional[int] = None,
               softmax_scale: Optional[float] = None):
    """One-token decode. q: (B, H, Dh); caches (B, S, Hkv, Dh); ``index`` =
    position of the new token (cache already contains it at ``index``)."""
    B, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Hkv, G, Dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    valid = pos <= index
    if window is not None:
        valid &= pos > (index - window)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)
