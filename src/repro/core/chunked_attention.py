"""Memory-bounded attention in pure JAX ("XLA-flash") — the train/prefill
attention path used by the dry-run and large-shape lowering.

Why not the Pallas kernel here?  On this CPU container Pallas lowers only in
interpret mode (emulation HLO pollutes the roofline); on a real TPU the
Pallas flash kernel (kernels/flash_attention.py) is the drop-in upgrade
(``impl='kernel'``).  This path guarantees the compiled HLO never holds an
(Lq, Lk) tensor: a ``lax.scan`` over q-blocks computes each block's scores
against the full K width, softmaxes, and reduces — peak live score memory
is (B, block_q, H, Lk).

A ``custom_vjp`` mirrors the scan in the backward pass (recompute-from-lse,
flash-attention style), so autodiff stores only (q, k, v, out, lse) — NOT
the per-block probability tensors.

Layouts match the model stack: q (B, Lq, H, D); k, v (B, Lk, Hkv, D*).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int], lk_valid: int):
    """(bq, Lk) bool mask."""
    m = k_pos[None, :] < lk_valid
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _fwd_block(qb, k, v, q_pos, *, scale, causal, window, lk_valid):
    """qb: (B,bq,Hkv,G,D); k,v: (B,Lk,Hkv,D*). Returns (out, lse).

    Native-dtype dots with fp32 accumulation (MXU semantics); an explicit
    astype(f32) on K/V is loop-invariant w.r.t. the q-block scan and XLA
    would hoist it into a full f32 HBM copy of K/V."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qb.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(k.shape[1])
    mask = _block_mask(q_pos, k_pos, causal, window, lk_valid)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)  # fully-masked rows stay finite
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32) \
        / jnp.maximum(l, 1e-30)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    return out, lse


def _pad_q(q, bq):
    Lq = q.shape[1]
    pad = -Lq % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return q, q.shape[1] // bq


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def chunked_attention(q, k, v, causal: bool = True,
                      window: Optional[int] = None, q_offset: int = 0,
                      softmax_scale: Optional[float] = None,
                      block_q: int = 256):
    """q: (B, Lq, H, Dqk); k, v: (B, Lk, Hkv, D) -> (B, Lq, H, Dv)."""
    out, _ = _chunked_fwd(q, k, v, causal, window, q_offset, softmax_scale,
                          block_q)
    return out


# ----------------------------------------------------- block-pair variant --
#
# For causal (and windowed) self-attention the q-block scan above still
# computes scores against the FULL key width — 2x wasted FLOPs for causal,
# far more for sliding windows.  The pair-scan iterates only the (q-block,
# k-block) pairs inside the mask support (the flash-attention grid as a
# lax.scan), with the online-softmax state as the carry.
# EXPERIMENTS.md §Perf B2.


def _pair_list(nq, nk, bq, bk, q_offset, causal, window, lk_valid):
    """Static list of (i, j) block pairs intersecting the mask support."""
    pairs = []
    for i in range(nq):
        q_lo, q_hi = q_offset + i * bq, q_offset + (i + 1) * bq - 1
        for j in range(nk):
            k_lo, k_hi = j * bk, (j + 1) * bk - 1
            if k_lo >= lk_valid:
                continue
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi <= q_lo - window:
                continue
            pairs.append((i, j))
    return pairs


def chunked_attention_pairs(q, k, v, causal: bool = True,
                            window: Optional[int] = None, q_offset: int = 0,
                            softmax_scale: Optional[float] = None,
                            block_q: int = 256, block_k: int = 256):
    """Mask-aware block-pair attention; same contract as chunked_attention.

    FLOPs scale with the mask support: ~(nq+1)/(2*nq) of full for causal,
    ~(window + bq)/Lk for sliding windows."""
    return _pairs_vjp(q, k, v, causal, window, q_offset, softmax_scale,
                      block_q, block_k)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pairs_vjp(q, k, v, causal, window, q_offset, softmax_scale, block_q,
               block_k):
    out, _ = _pairs_fwd(q, k, v, causal, window, q_offset, softmax_scale,
                        block_q, block_k)
    return out


def _pairs_setup(q, k, v, block_q, block_k):
    B, Lq, H, Dqk = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    pad_q, pad_k = -Lq % bq, -Lk % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    return q, k, v, bq, bk, q.shape[1] // bq, k.shape[1] // bk, Lq, Lk


def _block_mask_pair(i, j, bq, bk, q_offset, causal, window, lk_valid):
    q_pos = q_offset + i * bq + jnp.arange(bq)
    k_pos = j * bk + jnp.arange(bk)
    m = k_pos[None, :] < lk_valid
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _pairs_fwd(q, k, v, causal, window, q_offset, softmax_scale, block_q,
               block_k):
    q0 = q
    scale = softmax_scale if softmax_scale is not None else q.shape[-1] ** -0.5
    q, k, v, bq, bk, nq, nk, Lq, Lk = _pairs_setup(q, k, v, block_q, block_k)
    B, Lqp, H, Dqk = q.shape
    Hkv, Dv = k.shape[2], v.shape[-1]
    G = H // Hkv
    pairs = _pair_list(nq, nk, bq, bk, q_offset, causal, window, Lk)
    ii = jnp.array([p[0] for p in pairs])
    jj = jnp.array([p[1] for p in pairs])
    cdt = k.dtype

    def body(carry, ij):
        m_st, l_st, acc = carry              # (B,Lqp,H) f32, (B,Lqp,H,Dv) f32
        i, j = ij
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, 1)
        kb = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, 1)
        qg = qb.reshape(B, bq, Hkv, G, Dqk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(cdt), kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask_pair(i, j, bq, bk, q_offset, causal, window, Lk)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_rows = jax.lax.dynamic_slice_in_dim(m_st, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G)
        l_rows = jax.lax.dynamic_slice_in_dim(l_st, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G)
        a_rows = jax.lax.dynamic_slice_in_dim(acc, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G, Dv)
        m_new = jnp.maximum(m_rows, jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.where(mask[None, :, None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_rows - m_new)
        l_new = l_rows * corr + jnp.sum(p, axis=-1)
        a_new = a_rows * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(cdt), vb,
            preferred_element_type=jnp.float32)
        upd = lambda st, rows: jax.lax.dynamic_update_slice_in_dim(
            st, rows.reshape((B, bq) + st.shape[2:]), i * bq, 1)
        return (upd(m_st, m_new), upd(l_st, l_new), upd(acc, a_new)), ()

    m0 = jnp.full((B, Lqp, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Lqp, H), jnp.float32)
    a0 = jnp.zeros((B, Lqp, H, Dv), jnp.float32)
    (m_st, l_st, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ii, jj))
    l_safe = jnp.maximum(l_st, 1e-30)
    out = (acc / l_safe[..., None])[:, :Lq].astype(q0.dtype)
    lse = (m_st + jnp.log(l_safe))[:, :Lq]
    return out, (q0, k[:, :Lk], v[:, :Lk], out, lse)


def _pairs_bwd(causal, window, q_offset, softmax_scale, block_q, block_k,
               res, dout):
    q0, k0, v0, out, lse = res
    scale = softmax_scale if softmax_scale is not None else q0.shape[-1] ** -0.5
    q, k, v, bq, bk, nq, nk, Lq, Lk = _pairs_setup(q0, k0, v0, block_q, block_k)
    B, Lqp, H, Dqk = q.shape
    Hkv, Dv = k.shape[2], v.shape[-1]
    G = H // Hkv
    Lkp = k.shape[1]
    pad4 = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0), (0, 0))) if n else x
    pad3 = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0))) if n else x
    do = pad4(dout, Lqp - Lq)
    ob = pad4(out, Lqp - Lq)
    lsep = pad3(lse, Lqp - Lq)
    delta = jnp.sum(do.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)
    pairs = _pair_list(nq, nk, bq, bk, q_offset, causal, window, Lk)
    ii = jnp.array([p[0] for p in pairs])
    jj = jnp.array([p[1] for p in pairs])
    cdt = k.dtype

    def body(carry, ij):
        dq_st, dk_st, dv_st = carry
        i, j = ij
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G, Dqk)
        kb = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, 1)
        dob = jax.lax.dynamic_slice_in_dim(do, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G, Dv)
        lseb = jax.lax.dynamic_slice_in_dim(lsep, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G)
        dlb = jax.lax.dynamic_slice_in_dim(delta, i * bq, bq, 1) \
            .reshape(B, bq, Hkv, G)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qb.astype(cdt), kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask_pair(i, j, bq, bk, q_offset, causal, window, Lk)
        p = jnp.where(mask[None, :, None, None, :],
                      jnp.exp(s - lseb[..., None]), 0.0)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dob.astype(cdt), vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlb[..., None]) * scale
        dq_blk = jnp.einsum("bqhgk,bkhd->bqhgd", ds.astype(cdt), kb,
                            preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bqhgk,bqhgd->bkhd", ds.astype(cdt),
                            qb.astype(cdt), preferred_element_type=jnp.float32)
        dv_blk = jnp.einsum("bqhgk,bqhgd->bkhd", p.astype(cdt),
                            dob.astype(cdt), preferred_element_type=jnp.float32)
        dq_rows = jax.lax.dynamic_slice_in_dim(dq_st, i * bq, bq, 1) \
            + dq_blk.reshape(B, bq, H, Dqk)
        dk_rows = jax.lax.dynamic_slice_in_dim(dk_st, j * bk, bk, 1) + dk_blk
        dv_rows = jax.lax.dynamic_slice_in_dim(dv_st, j * bk, bk, 1) + dv_blk
        return (jax.lax.dynamic_update_slice_in_dim(dq_st, dq_rows, i * bq, 1),
                jax.lax.dynamic_update_slice_in_dim(dk_st, dk_rows, j * bk, 1),
                jax.lax.dynamic_update_slice_in_dim(dv_st, dv_rows, j * bk, 1)), ()

    dq0 = jnp.zeros((B, Lqp, H, Dqk), jnp.float32)
    dk0 = jnp.zeros((B, Lkp, Hkv, Dqk), jnp.float32)
    dv0 = jnp.zeros((B, Lkp, Hkv, Dv), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (ii, jj))
    return (dq[:, :Lq].astype(q0.dtype), dk[:, :Lk].astype(k0.dtype),
            dv[:, :Lk].astype(v0.dtype))


_pairs_vjp.defvjp(_pairs_fwd, _pairs_bwd)


def _chunked_fwd(q, k, v, causal, window, q_offset, softmax_scale, block_q):
    B, Lq, H, Dqk = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dqk ** -0.5
    bq = min(block_q, Lq)
    qp, nq = _pad_q(q, bq)
    qb = qp.reshape(B, nq, bq, Hkv, G, Dqk).swapaxes(0, 1)  # (nq,B,bq,Hkv,G,D)

    def body(_, args):
        i, qblk = args
        q_pos = q_offset + i * bq + jnp.arange(bq)
        o, lse = _fwd_block(qblk, k, v, q_pos, scale=scale, causal=causal,
                            window=window, lk_valid=Lk)
        return (), (o, lse)

    _, (ob, lseb) = jax.lax.scan(body, (), (jnp.arange(nq), qb))
    out = ob.swapaxes(0, 1).reshape(B, nq * bq, H, v.shape[-1])[:, :Lq]
    lse = lseb.swapaxes(0, 1).reshape(B, nq * bq, Hkv, G)[:, :Lq]
    return out.astype(q.dtype), (q, k, v, out.astype(q.dtype), lse)


def _chunked_bwd(causal, window, q_offset, softmax_scale, block_q, res, dout):
    q, k, v, out, lse = res
    B, Lq, H, Dqk = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dqk ** -0.5
    bq = min(block_q, Lq)
    pad = -Lq % bq
    nq = (Lq + pad) // bq
    pad4 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else x
    qb = pad4(q).reshape(B, nq, bq, Hkv, G, Dqk).swapaxes(0, 1)
    dob = pad4(dout).reshape(B, nq, bq, Hkv, G, -1).swapaxes(0, 1)
    ob = pad4(out).reshape(B, nq, bq, Hkv, G, -1).swapaxes(0, 1)
    lseb = (jnp.pad(lse, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else lse) \
        .reshape(B, nq, bq, Hkv, G).swapaxes(0, 1)
    k_pos = jnp.arange(Lk)
    cdt = k.dtype

    def body(carry, args):
        dk_acc, dv_acc = carry
        i, qblk, doblk, oblk, lseblk = args
        q_pos = q_offset + i * bq + jnp.arange(bq)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk.astype(cdt), k,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(q_pos, k_pos, causal, window, Lk)
        p = jnp.where(mask[None, :, None, None, :],
                      jnp.exp(s - lseblk[..., None]), 0.0)
        dof = doblk.astype(jnp.float32)
        delta = jnp.sum(dof * oblk.astype(jnp.float32), axis=-1, keepdims=True)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", doblk.astype(cdt), v,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dq_blk = jnp.einsum("bqhgk,bkhd->bqhgd", ds.astype(cdt), k,
                            preferred_element_type=jnp.float32)
        dk_acc = dk_acc + jnp.einsum("bqhgk,bqhgd->bkhd", ds.astype(cdt),
                                     qblk.astype(cdt),
                                     preferred_element_type=jnp.float32)
        dv_acc = dv_acc + jnp.einsum("bqhgk,bqhgd->bkhd", p.astype(cdt),
                                     doblk.astype(cdt),
                                     preferred_element_type=jnp.float32)
        return (dk_acc, dv_acc), dq_blk

    zeros_k = jnp.zeros((B, Lk, Hkv, Dqk), jnp.float32)
    zeros_v = jnp.zeros((B, Lk, Hkv, v.shape[-1]), jnp.float32)
    (dk, dv), dqb = jax.lax.scan(
        body, (zeros_k, zeros_v), (jnp.arange(nq), qb, dob, ob, lseb))
    dq = dqb.swapaxes(0, 1).reshape(B, nq * bq, H, Dqk)[:, :Lq]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


chunked_attention.defvjp(_chunked_fwd, _chunked_bwd)
