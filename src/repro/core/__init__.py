from . import attention, cache, mla, schemes
from .mla import MLAConfig, mla_decode, mla_defs, mla_prefill, prepare_serving, SCHEMES
from .schemes import PlatformPoint, auto_dispatch

__all__ = [
    "attention", "cache", "mla", "schemes",
    "MLAConfig", "mla_decode", "mla_defs", "mla_prefill", "prepare_serving",
    "SCHEMES", "PlatformPoint", "auto_dispatch",
]
