"""Decode-time state pytrees: dense KV cache, MLA latent cache, SSM state.

All caches are plain dicts of arrays (pytree-friendly for pjit donation).
``index`` is the number of valid tokens already in the cache; new tokens are
written at ``index`` and attention masks positions ``>= index+1``.

Shapes (S = capacity):
  KVCache      : k (B,S,Hkv,Dh)  v (B,S,Hkv,Dh)
  LatentCache  : ckv (B,S,D_kvl)  krope (B,S,D_rope)        <- the paper's
                 compact cache: (D_kvl + D_rope) bytes/token vs
                 2*Hkv*Dh for dense KV.
  MambaState   : conv (B,W-1,C)  ssm (B,C,N)
  XLSTMState   : mLSTM matrix memory + normalizer, sLSTM registers
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    lead = (layers,) if layers else ()
    return {
        "k": jnp.zeros(lead + (batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros(lead + (batch, capacity, n_kv, head_dim), dtype),
    }


def latent_cache(batch: int, capacity: int, kv_lora: int, rope_dim: int,
                 dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    """Split layout {ckv | krope} — (D_kvl + D_rope) bytes/token total, the
    paper's compact cache.  The split (vs a fused [ckv|krope] array) lets
    the PV contraction read ``ckv`` directly: a fused layout needs a
    ``kv[..., :D_kvl]`` slice every layer, a real copy on TPU measured at
    ~0.9 GB/chip/step on the deepseek-v2 decode_32k cell
    (EXPERIMENTS.md §Perf A3)."""
    lead = (layers,) if layers else ()
    return {
        "ckv": jnp.zeros(lead + (batch, capacity, kv_lora), dtype),
        "krope": jnp.zeros(lead + (batch, capacity, rope_dim), dtype),
    }


def mamba_state(batch: int, d_inner: int, d_state: int, conv_width: int,
                dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    lead = (layers,) if layers else ()
    return {
        "conv": jnp.zeros(lead + (batch, conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros(lead + (batch, d_inner, d_state), jnp.float32),
    }


def update_kv(cache: Dict[str, Any], k_new, v_new, index) -> Dict[str, Any]:
    """Write (B, Lnew, Hkv, Dh) at position ``index`` along the seq axis."""
    out = dict(cache)
    out["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
    out["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    return out


def update_latent(cache: Dict[str, Any], ckv_new, krope_new, index) -> Dict[str, Any]:
    """Write (B, Lnew, D_kvl) + (B, Lnew, D_rope) at ``index``."""
    return {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), index, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope_new.astype(cache["krope"].dtype), index,
            axis=1),
    }


def valid_mask(capacity: int, index, n_new: int = 1):
    """(n_new, capacity) bool mask: new token i may attend cache pos j iff
    j <= index + i (cache already contains the new tokens when scored)."""
    j = jnp.arange(capacity)
    i = jnp.arange(n_new)
    return j[None, :] <= (index + i[:, None])


def bytes_per_token_dense(n_kv: int, head_dim: int, dtype_bytes: int = 2) -> int:
    return 2 * n_kv * head_dim * dtype_bytes


def bytes_per_token_latent(kv_lora: int, rope_dim: int, dtype_bytes: int = 2) -> int:
    return (kv_lora + rope_dim) * dtype_bytes
