"""Decode-time state pytrees: dense KV cache, MLA latent cache, SSM state.

All caches are plain dicts of arrays (pytree-friendly for pjit donation).
``index`` is the number of valid tokens already in the cache; new tokens are
written at ``index`` and attention masks positions ``>= index+1``.

Shapes (S = capacity):
  KVCache      : k (B,S,Hkv,Dh)  v (B,S,Hkv,Dh)
  LatentCache  : ckv (B,S,D_kvl)  krope (B,S,D_rope)        <- the paper's
                 compact cache: (D_kvl + D_rope) bytes/token vs
                 2*Hkv*Dh for dense KV.
  MambaState   : conv (B,W-1,C)  ssm (B,C,N)
  XLSTMState   : mLSTM matrix memory + normalizer, sLSTM registers
  PagedLatent  : ckv (N,bs,D_kvl)  krope (N,bs,D_rope) — a global block
                 pool addressed via per-request block tables (continuous
                 batching; see the "paged" section below).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    lead = (layers,) if layers else ()
    return {
        "k": jnp.zeros(lead + (batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros(lead + (batch, capacity, n_kv, head_dim), dtype),
    }


def latent_cache(batch: int, capacity: int, kv_lora: int, rope_dim: int,
                 dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    """Split layout {ckv | krope} — (D_kvl + D_rope) bytes/token total, the
    paper's compact cache.  The split (vs a fused [ckv|krope] array) lets
    the PV contraction read ``ckv`` directly: a fused layout needs a
    ``kv[..., :D_kvl]`` slice every layer, a real copy on TPU measured at
    ~0.9 GB/chip/step on the deepseek-v2 decode_32k cell
    (EXPERIMENTS.md §Perf A3)."""
    lead = (layers,) if layers else ()
    return {
        "ckv": jnp.zeros(lead + (batch, capacity, kv_lora), dtype),
        "krope": jnp.zeros(lead + (batch, capacity, rope_dim), dtype),
    }


def mamba_state(batch: int, d_inner: int, d_state: int, conv_width: int,
                dtype=jnp.bfloat16, layers: Optional[int] = None) -> Dict[str, Any]:
    lead = (layers,) if layers else ()
    return {
        "conv": jnp.zeros(lead + (batch, conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros(lead + (batch, d_inner, d_state), jnp.float32),
    }


def update_kv(cache: Dict[str, Any], k_new, v_new, index) -> Dict[str, Any]:
    """Write (B, Lnew, Hkv, Dh) at position ``index`` along the seq axis."""
    out = dict(cache)
    out["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
    out["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    return out


def update_latent(cache: Dict[str, Any], ckv_new, krope_new, index) -> Dict[str, Any]:
    """Write (B, Lnew, D_kvl) + (B, Lnew, D_rope) at ``index``."""
    return {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), index, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope_new.astype(cache["krope"].dtype), index,
            axis=1),
    }


# ---------------------------------------------------------------- paged ----
#
# vLLM-style paged layout for the latent cache: a global pool of fixed-size
# token blocks shared by all requests, addressed through per-request block
# tables.  The {ckv | krope} split is preserved (two pools, same block
# geometry) so the PV contraction still reads ``ckv`` directly — no
# [ckv|krope] slice, same no-copy property as the contiguous layout.
#
# Conventions (shared by kernels/, core/mla.py and runtime/scheduler.py):
#   * pool shapes: ckv (N_blocks, bs, D_kvl), krope (N_blocks, bs, D_rope)
#   * block 0 is the reserved NULL block — the allocator never hands it
#     out and unassigned block-table entries point at it, so every gather
#     and block-table-driven DMA stays in-bounds.
#   * ``block_table`` (B, max_blocks) int32 maps request-local block j to
#     a pool block; ``lengths`` (B,) int32 counts tokens already cached
#     (the next decode token is written at position lengths[b]).


# -------------------------------------------------- quantized storage ----
#
# The pool optionally stores {ckv|krope} quantized (int8, or fp8-e4m3 where
# the installed jax exposes it) with one f32 scale per TOKEN SLOT carried as
# extra pool leaves ``ckv_scale``/``krope_scale`` shaped (N, bs, 1).  Scale
# leaves ride the pool pytree, so donation aliasing, PS() replication,
# copy-on-write (copy_block_paged) and sharding rules all apply to them with
# no extra plumbing.  Convention: stored q ~= x / scale, dequant
# x ~= q.astype(f32) * scale, with scale = amax(|x|, row) / qmax (amax == 0
# rows get scale 1 so the null block stays exactly zero).

CACHE_DTYPES = ("bf16", "int8", "fp8")


def cache_dtype_info(cache_dtype: Optional[str]):
    """Map a ``--cache-dtype`` name to (storage jnp dtype, qmax).

    qmax is None for unquantized storage (bf16 keeps the pool at the
    caller's compute dtype, the pre-quantization behavior)."""
    if cache_dtype in (None, "bf16", "bfloat16"):
        return None, None
    if cache_dtype == "int8":
        return jnp.int8, 127.0
    if cache_dtype == "fp8":
        if not hasattr(jnp, "float8_e4m3fn"):
            raise ValueError("fp8 cache requested but this jax build has no "
                             "float8_e4m3fn dtype")
        return jnp.float8_e4m3fn, 448.0
    raise ValueError(f"unknown cache_dtype {cache_dtype!r}; "
                     f"expected one of {CACHE_DTYPES}")


def is_quantized_pool(pool: Dict[str, Any]) -> bool:
    return "ckv_scale" in pool


def cache_dtype_qmax(qdtype) -> float:
    """qmax for a quantized STORAGE dtype (int8 or fp8-e4m3)."""
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        return 127.0
    return 448.0


def quantize_latent(x, qmax: float, qdtype):
    """Per-token-row symmetric quantization.

    Returns (q, scale): q has x.shape in ``qdtype``, scale has
    x.shape[:-1] + (1,) in f32, and x ~= q.astype(f32) * scale."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    q = x32 / scale
    if jnp.issubdtype(jnp.dtype(qdtype), jnp.integer):
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q.astype(qdtype), scale


def dequantize_latent(q, scale):
    """Inverse of quantize_latent — f32 result."""
    return q.astype(jnp.float32) * scale


def paged_latent_cache(num_blocks: int, block_size: int, kv_lora: int,
                       rope_dim: int, dtype=jnp.bfloat16,
                       layers: Optional[int] = None,
                       cache_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Paged split-layout latent pool (block 0 = null block).

    ``cache_dtype`` in {int8, fp8} adds per-token-slot f32 scale leaves and
    stores the data leaves quantized; bf16/None keeps the pool at ``dtype``.
    """
    lead = (layers,) if layers else ()
    qdtype, qmax = cache_dtype_info(cache_dtype)
    if qmax is None:
        return {
            "ckv": jnp.zeros(lead + (num_blocks, block_size, kv_lora), dtype),
            "krope": jnp.zeros(lead + (num_blocks, block_size, rope_dim),
                               dtype),
        }
    return {
        "ckv": jnp.zeros(lead + (num_blocks, block_size, kv_lora), qdtype),
        "ckv_scale": jnp.ones(lead + (num_blocks, block_size, 1),
                              jnp.float32),
        "krope": jnp.zeros(lead + (num_blocks, block_size, rope_dim), qdtype),
        "krope_scale": jnp.ones(lead + (num_blocks, block_size, 1),
                                jnp.float32),
    }


def update_latent_paged(pool: Dict[str, Any], block_table, lengths,
                        ckv_new, krope_new) -> Dict[str, Any]:
    """Scatter one new token per request into the pool.

    ckv_new (B, D_kvl), krope_new (B, D_rope) land at position lengths[b]:
    pool block ``block_table[b, lengths[b] // bs]``, slot ``lengths[b] % bs``.
    The caller (runtime.scheduler) guarantees that block is allocated AND
    that ``lengths[b] < block_table.shape[1] * bs``: a full table is NOT
    detected here — JAX clamps the out-of-range page index, which would
    silently overwrite the request's last block.
    ``ContinuousScheduler._require_table_room`` raises on the host before
    any step could reach that clamp.
    """
    bs = pool["ckv"].shape[-2]
    lengths = jnp.asarray(lengths, jnp.int32)
    page = jnp.take_along_axis(jnp.asarray(block_table, jnp.int32),
                               (lengths // bs)[:, None], axis=1)[:, 0]
    slot = lengths % bs
    if is_quantized_pool(pool):
        qmax = cache_dtype_qmax(pool["ckv"].dtype)
        ckv_q, ckv_s = quantize_latent(ckv_new, qmax, pool["ckv"].dtype)
        kr_q, kr_s = quantize_latent(krope_new, qmax, pool["krope"].dtype)
        return {
            "ckv": pool["ckv"].at[page, slot].set(ckv_q),
            "ckv_scale": pool["ckv_scale"].at[page, slot].set(ckv_s),
            "krope": pool["krope"].at[page, slot].set(kr_q),
            "krope_scale": pool["krope_scale"].at[page, slot].set(kr_s),
        }
    return {
        "ckv": pool["ckv"].at[page, slot].set(
            ckv_new.astype(pool["ckv"].dtype)),
        "krope": pool["krope"].at[page, slot].set(
            krope_new.astype(pool["krope"].dtype)),
    }


def update_latent_paged_chunk(pool: Dict[str, Any], block_table, lengths,
                              n_valid, ckv_new, krope_new) -> Dict[str, Any]:
    """Scatter a CHUNK of new tokens per request into the pool (batched
    chunked prefill).

    ckv_new (B, C, D_kvl), krope_new (B, C, D_rope): row b's chunk token c
    is valid iff ``c < n_valid[b]`` and lands at absolute position
    ``lengths[b] + c`` (pool block ``block_table[b, pos // bs]``, slot
    ``pos % bs``).  Invalid tokens (chunk padding, idle batch rows) are
    routed to the NULL block — block 0 absorbs the garbage and is never
    attended (every mask excludes positions past each request's length).
    The caller guarantees every VALID position has an allocated block.
    """
    bs = pool["ckv"].shape[-2]
    bt = jnp.asarray(block_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    C = ckv_new.shape[1]
    pos = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    blk = jnp.clip(pos // bs, 0, bt.shape[1] - 1)
    page = jnp.where(valid, jnp.take_along_axis(bt, blk, axis=1), 0)
    slot = pos % bs
    if is_quantized_pool(pool):
        qmax = cache_dtype_qmax(pool["ckv"].dtype)
        ckv_q, ckv_s = quantize_latent(ckv_new, qmax, pool["ckv"].dtype)
        kr_q, kr_s = quantize_latent(krope_new, qmax, pool["krope"].dtype)
        return {
            "ckv": pool["ckv"].at[page, slot].set(ckv_q),
            "ckv_scale": pool["ckv_scale"].at[page, slot].set(ckv_s),
            "krope": pool["krope"].at[page, slot].set(kr_q),
            "krope_scale": pool["krope_scale"].at[page, slot].set(kr_s),
        }
    return {
        "ckv": pool["ckv"].at[page, slot].set(
            ckv_new.astype(pool["ckv"].dtype)),
        "krope": pool["krope"].at[page, slot].set(
            krope_new.astype(pool["krope"].dtype)),
    }


def copy_block_paged(pool_tree, src: int, dst: int):
    """Copy one pool block's contents (all leaves, all layers) from block
    ``src`` to block ``dst`` — the device side of a copy-on-write break:
    the scheduler swaps a shared write-target block for a private copy
    (runtime.scheduler._cow_write_target) and the engine runs this copy
    before the next pool write."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def leaf(a):
        if a.ndim == 4:     # stacked (scan) layers: (layers, N, bs, D)
            return a.at[:, dst].set(a[:, src])
        return a.at[dst].set(a[src])

    return jax.tree.map(leaf, pool_tree)


def copy_blocks_paged(pool_tree, srcs, dsts):
    """Batched :func:`copy_block_paged`: copy pool blocks srcs[i] -> dsts[i]
    (all leaves, all layers) in ONE device op — the engine drains a tick's
    whole copy-on-write queue (write-share breaks, partial prefix-match
    tails, n-way fork tails) in a single dispatch instead of one jit call
    per pair.  Pairs must be independent: every src is gathered before any
    dst is written, so a dst reused as a later src would read stale data —
    the engine falls back to in-order :func:`copy_block_paged` calls for
    (rare) chained batches.  (0, 0) pairs are no-ops on the reserved null
    block; callers pad the pair count with them to bound compile variants.
    """
    srcs = jnp.asarray(srcs, jnp.int32)
    dsts = jnp.asarray(dsts, jnp.int32)

    def leaf(a):
        if a.ndim == 4:     # stacked (scan) layers: (layers, N, bs, D)
            return a.at[:, dsts].set(a[:, srcs])
        return a.at[dsts].set(a[srcs])

    return jax.tree.map(leaf, pool_tree)


def gather_latent_paged(pool: Dict[str, Any], block_table):
    """Materialize the contiguous (B, max_blocks*bs, D) view of each
    request's cache — the reference/naive path (the kernel path reads the
    pool in place via the block table and never builds this)."""
    bt = jnp.asarray(block_table, jnp.int32)
    B, nb = bt.shape
    bs = pool["ckv"].shape[-2]
    ckv = pool["ckv"][bt].reshape(B, nb * bs, pool["ckv"].shape[-1])
    krope = pool["krope"][bt].reshape(B, nb * bs, pool["krope"].shape[-1])
    if is_quantized_pool(pool):
        # Dequantize the GATHERED view (f32), never the pool itself: an
        # astype on the pool would hoist a full-precision HBM copy of the
        # whole pool (the hazard core/mla.py's dtype NOTE documents, and
        # analysis.audit flags).
        ckv_s = pool["ckv_scale"][bt].reshape(B, nb * bs, 1)
        kr_s = pool["krope_scale"][bt].reshape(B, nb * bs, 1)
        return dequantize_latent(ckv, ckv_s), dequantize_latent(krope, kr_s)
    return ckv, krope


def write_blocks_paged(pool_leaf, pages, values):
    """Bulk-write whole blocks (prefill -> paged handoff).

    pool_leaf: (N, bs, D) or stacked (layers, N, bs, D);
    pages: (n_pg,) int32 pool-block ids (null-block entries absorb the
    padding garbage — it is masked at attention time);
    values: (n_pg, bs, D) or (layers, n_pg, bs, D).
    """
    pages = jnp.asarray(pages, jnp.int32)
    if pool_leaf.ndim == 4:   # stacked (scan) layers
        return pool_leaf.at[:, pages].set(values.astype(pool_leaf.dtype))
    return pool_leaf.at[pages].set(values.astype(pool_leaf.dtype))


def paged_valid_mask(capacity: int, lengths):
    """(B, capacity) bool mask over the gathered view: request b may attend
    positions <= lengths[b] (its new token was already written there)."""
    j = jnp.arange(capacity)
    return j[None, :] <= jnp.asarray(lengths)[:, None]


def valid_mask(capacity: int, index, n_new: int = 1):
    """(n_new, capacity) bool mask: new token i may attend cache pos j iff
    j <= index + i (cache already contains the new tokens when scored)."""
    j = jnp.arange(capacity)
    i = jnp.arange(n_new)
    return j[None, :] <= (index + i[:, None])


def bytes_per_token_dense(n_kv: int, head_dim: int, dtype_bytes: int = 2) -> int:
    return 2 * n_kv * head_dim * dtype_bytes


def bytes_per_token_latent(kv_lora: int, rope_dim: int, dtype_bytes: int = 2,
                           cache_dtype: Optional[str] = None) -> float:
    """Latent-cache bytes per cached token.  Quantized storage pays 1 byte
    per element plus two f32 per-token-row scales ({ckv|krope} split)."""
    qdtype, qmax = cache_dtype_info(cache_dtype)
    if qmax is None:
        return (kv_lora + rope_dim) * dtype_bytes
    return (kv_lora + rope_dim) * 1 + 2 * 4


def cache_element_bytes(kv_lora: int, rope_dim: int, dtype_bytes: int = 2,
                        cache_dtype: Optional[str] = None) -> float:
    """Effective bytes per latent-cache ELEMENT (scale overhead amortized
    over the (D_kvl + D_rope) row) — the bytes-per-element axis the hwmodel
    cost terms multiply by."""
    tok = bytes_per_token_latent(kv_lora, rope_dim, dtype_bytes, cache_dtype)
    return tok / float(kv_lora + rope_dim)
