"""Runtime execution-scheme dispatch — the paper's co-design insight made
executable: pick MLA_rc vs MLA_ru (vs seq) from the platform's
compute-to-bandwidth ratio, batch size and cache length.

The decision rule is the roofline argument of the paper's Fig 5: estimate
per-step time  t = max(flops/peak, bytes/bw)  for each scheme from the
closed-form costs in ``repro.hwmodel.attention_costs`` and take argmin.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .mla import MLAConfig


@dataclasses.dataclass(frozen=True)
class PlatformPoint:
    name: str
    peak_flops: float      # FLOP/s (bf16)
    hbm_bw: float          # B/s
    dtype_bytes: int = 2

    @property
    def ridge_oi(self) -> float:
        return self.peak_flops / self.hbm_bw


def cache_width(cfg: MLAConfig, platform: PlatformPoint,
                cache_dtype: Optional[str] = None) -> float:
    """Per-element byte width of the latent pool under ``cache_dtype``
    (None / 'bf16' -> the platform's compute width; 'int8' / 'fp8' -> the
    1-byte payload plus the per-row f32 scale overhead amortized over the
    row, see core.cache.cache_element_bytes).  Every roofline entry point
    below funnels its cache terms through this so the dispatcher, the
    drift channel and the bench report price the same pool."""
    from .cache import cache_element_bytes  # local import: no cycle
    return cache_element_bytes(cfg.kv_lora_rank, cfg.qk_rope_dim,
                               dtype_bytes=platform.dtype_bytes,
                               cache_dtype=cache_dtype)


def step_time(scheme: str, cfg: MLAConfig, platform: PlatformPoint,
              cache_len: int, batch: int = 1,
              paged_block: int = 0, dp_shards: int = 1,
              cache_dtype: Optional[str] = None) -> float:
    """``paged_block > 0``: cost the paged latent cache (whole-block reads
    + block-table traffic).  ``dp_shards > 1``: per-DEVICE roofline of
    data-parallel serving — the batch-proportional cache terms shrink to
    the local batch while weight bytes stay whole (the devices run in
    lockstep, so the slowest == any one device; see
    hwmodel.attention_costs.mla_decode_cost).  ``cache_dtype`` prices a
    quantized latent pool (:func:`cache_width`): the cache streams
    shrink while weights/activations stay at the compute width."""
    from ..hwmodel import attention_costs as ac  # local import: no cycle
    c = ac.mla_decode_cost(cfg, scheme=scheme, cache_len=cache_len,
                           batch=batch, dtype_bytes=platform.dtype_bytes,
                           paged_block=paged_block, dp_shards=dp_shards,
                           cache_dtype_bytes=cache_width(cfg, platform,
                                                         cache_dtype))
    return max(c.flops / platform.peak_flops, c.bytes / platform.hbm_bw)


def verify_time(scheme: str, cfg: MLAConfig, platform: PlatformPoint,
                cache_len: int, k: int, batch: int = 1,
                paged_block: int = 0, dp_shards: int = 1,
                cache_dtype: Optional[str] = None) -> float:
    """Roofline time of one SPECULATIVE verify step (k + 1 query
    positions against the resident cache in one forward — see
    hwmodel.attention_costs.mla_verify_cost).  The spec-decode engine
    dispatches its verify scheme on this instead of :func:`step_time`:
    the k-token window amortizes weight and cache streams, which moves
    the rc/ru/seq crossover points relative to single-token decode."""
    from ..hwmodel import attention_costs as ac  # local import: no cycle
    c = ac.mla_verify_cost(cfg, scheme=scheme, cache_len=cache_len, k=k,
                           batch=batch, dtype_bytes=platform.dtype_bytes,
                           paged_block=paged_block, dp_shards=dp_shards,
                           cache_dtype_bytes=cache_width(cfg, platform,
                                                         cache_dtype))
    return max(c.flops / platform.peak_flops, c.bytes / platform.hbm_bw)


def prefill_time(cfg: MLAConfig, platform: PlatformPoint, seq_len: int,
                 batch: int = 1, cached_prefix: int = 0,
                 chunk: int = 0, paged_block: int = 0,
                 impl: str = "pallas",
                 cache_dtype: Optional[str] = None) -> float:
    """Roofline TTFT estimate for one MLA layer's prefill; ``cached_prefix``
    tokens come from the radix prefix cache (runtime.prefix_cache), so
    only the suffix is projected/written while still attending the full
    prompt.  bench_serving uses the ratio of this at the measured hit
    rate vs 0 to report the modeled TTFT effect of prefix sharing.

    ``chunk > 0 and paged_block > 0`` costs the chunked PAGED prefill
    instead (hwmodel.attention_costs.mla_prefill_chunk_cost): ``impl``
    'gather' charges the materialized block-table view the reference
    path writes + re-reads every chunk, 'pallas' the in-place paged
    reads of the fused kernel — the arithmetic-intensity delta the
    prefill kernel exists to claw back."""
    from ..hwmodel import attention_costs as ac  # local import: no cycle
    cw = cache_width(cfg, platform, cache_dtype)
    if chunk and paged_block:
        c = ac.mla_prefill_chunk_cost(cfg, seq_len=seq_len, chunk=chunk,
                                      paged_block=paged_block, batch=batch,
                                      dtype_bytes=platform.dtype_bytes,
                                      cached_prefix=cached_prefix, impl=impl,
                                      cache_dtype_bytes=cw)
    else:
        c = ac.mla_prefill_cost(cfg, seq_len=seq_len, batch=batch,
                                dtype_bytes=platform.dtype_bytes,
                                cached_prefix=cached_prefix,
                                cache_dtype_bytes=cw)
    return max(c.flops / platform.peak_flops, c.bytes / platform.hbm_bw)


def cow_copy_time(cfg: MLAConfig, platform: PlatformPoint,
                  paged_block: int, n_copies: int = 1,
                  cache_dtype: Optional[str] = None) -> float:
    """Roofline time of ``n_copies`` copy-on-write block copies in ONE
    MLA layer's latent pool: each copy streams a whole
    ``paged_block x (kv_lora_rank + qk_rope_dim)`` latent block out of
    HBM and back (read src + write dst — pure bandwidth, no FLOPs).
    This prices the device side of partial-hit tail materialization and
    write-target share breaking; the engine batches independent copies
    into one op, which changes launch overhead but not bytes moved."""
    bytes_per_block = (paged_block * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                       * cache_width(cfg, platform, cache_dtype))
    return 2.0 * n_copies * bytes_per_block / platform.hbm_bw


def fork_time(cfg: MLAConfig, platform: PlatformPoint, seq_len: int,
              n: int, paged_block: int,
              cache_dtype: Optional[str] = None) -> float:
    """Device cost of forking a just-prefilled sequence ``n`` ways for
    parallel sampling (runtime.scheduler.fork_group): the ``seq_len //
    paged_block`` FULL blocks are shared by reference — free on the
    device — and only a mid-block tail (``seq_len % paged_block != 0``)
    costs one CoW block copy per fork.  The contrast with n independent
    requests (n-1 extra prefills, or n-1 full cache re-reads on a
    perfect prefix hit) is the term bench_serving's fork rows report."""
    if n <= 1 or seq_len % paged_block == 0:
        return 0.0
    return cow_copy_time(cfg, platform, paged_block, n_copies=n - 1,
                         cache_dtype=cache_dtype)


def auto_dispatch(cfg: MLAConfig, platform: PlatformPoint, cache_len: int,
                  batch: int = 1, candidates=("seq", "rc", "ru"),
                  paged_block: int = 0, dp_shards: int = 1,
                  verify_k: int = 0,
                  cache_dtype: Optional[str] = None) -> str:
    """Return the fastest scheme for this (platform, cache, batch) point.

    The continuous-batching runtime calls this EVERY step on the live
    (batch, max cache_len) point, so the rc/ru/seq choice adapts as the
    batch composition changes (the paper: "the choice between them can be
    made dynamically").  Under data-parallel serving the engine passes
    ``dp_shards`` so the decision is made on the PER-DEVICE point (the
    local batch is what each device's roofline sees — a dispatch computed
    on the global batch would over-weight the batch-shared terms).

    ``verify_k > 0`` dispatches a SPECULATIVE verify step instead: the
    k + 1-query window amortizes the weight/cache streams all schemes
    share but multiplies the per-query FLOP terms, so the best verify
    scheme can differ from the best decode scheme at the same
    (batch, cache) point (:func:`verify_time`)."""
    if verify_k > 0:
        return min(candidates,
                   key=lambda s: verify_time(s, cfg, platform, cache_len,
                                             verify_k, batch,
                                             paged_block=paged_block,
                                             dp_shards=dp_shards,
                                             cache_dtype=cache_dtype))
    return min(candidates, key=lambda s: step_time(s, cfg, platform,
                                                   cache_len, batch,
                                                   paged_block=paged_block,
                                                   dp_shards=dp_shards,
                                                   cache_dtype=cache_dtype))
