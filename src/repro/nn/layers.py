"""Base layers: dense, norms, embedding, RoPE, MLPs, causal conv1d.

Every layer is a (defs, apply) pair of pure functions; params are nested
dicts produced by ``module.init_params``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .module import P

# ---------------------------------------------------------------- dense ----


def dense_defs(in_dim: int, out_dim: int, in_ax: Optional[str], out_ax: Optional[str],
               bias: bool = False, init: str = "fan_in", scale: Optional[float] = None):
    d = {"w": P((in_dim, out_dim), (in_ax, out_ax), init=init, scale=scale)}
    if bias:
        d["b"] = P((out_dim,), (out_ax,), init="zeros")
    return d


def dense(params, x, compute_dtype=None):
    w = params["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------------- norms ---


def rmsnorm_defs(dim: int, ax: Optional[str] = None):
    return {"scale": P((dim,), (ax,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_defs(dim: int, ax: Optional[str] = None):
    return {"scale": P((dim,), (ax,), init="ones"), "bias": P((dim,), (ax,), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------- embedding ---


def embed_defs(vocab: int, dim: int):
    return {"table": P((vocab, dim), ("vocab", "embed"), init="embed", scale=0.02)}


def embed(params, ids, compute_dtype=jnp.bfloat16):
    return jnp.take(params["table"], ids, axis=0).astype(compute_dtype)


def unembed(params, x):
    """Logits projection with the (possibly tied) embedding table."""
    table = params["table"].astype(x.dtype)
    return x @ table.T


# ------------------------------------------------------------------ RoPE ---


def rope_angles(positions, dim: int, base: float):
    """positions: (..., L) int -> cos,sin of shape (..., L, dim//2) f32."""
    half = dim // 2
    inv_freq = 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, base: float = 10000.0):
    """x: (B, L, H, D) or (B, L, D); positions: (B, L). Rotate-half (NeoX)."""
    d = x.shape[-1]
    cos, sin = rope_angles(positions, d, base)  # (B, L, d/2)
    if x.ndim == 4:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLPs ---


def mlp_defs(d_model: int, d_ff: int, kind: str = "swiglu", bias: bool = False):
    if kind == "swiglu":
        return {
            "wi": P((d_model, 2, d_ff), ("embed", None, "mlp")),  # [gate; up] fused
            "wo": P((d_ff, d_model), ("mlp", "embed")),
        }
    d = {"wi": dense_defs(d_model, d_ff, "embed", "mlp", bias=bias),
         "wo": dense_defs(d_ff, d_model, "mlp", "embed", bias=bias)}
    return d


def mlp(params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jnp.einsum("...d,dcf->...cf", x, params["wi"].astype(x.dtype))
        gate, up = h[..., 0, :], h[..., 1, :]
        h = jax.nn.silu(gate) * up
        return h @ params["wo"].astype(x.dtype)
    h = dense(params["wi"], x)
    h = jax.nn.gelu(h, approximate=True)
    return dense(params["wo"], h)


# -------------------------------------------------- causal depthwise conv ---


def causal_conv1d_defs(channels: int, width: int):
    return {"w": P((width, channels), (None, "mlp"), init="fan_in"),
            "b": P((channels,), ("mlp",), init="zeros")}


def causal_conv1d(params, x):
    """x: (B, L, C) -> (B, L, C), causal depthwise conv."""
    w, b = params["w"], params["b"]
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # depthwise: sum_k w[k, c] * x[:, t - (width-1) + k, c]
    out = jnp.zeros_like(x)
    for k in range(width):
        out = out + w[k].astype(x.dtype) * jax.lax.dynamic_slice_in_dim(
            pad, k, x.shape[1], axis=1)
    return out + b.astype(x.dtype)


def causal_conv1d_step(params, x_t, conv_state):
    """Single decode step. x_t: (B, C); conv_state: (B, width-1, C)."""
    w, b = params["w"], params["b"]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x_t.dtype) + b.astype(x_t.dtype)
    return out, window[:, 1:, :]
