"""Logical-axis -> mesh-axis rules (MaxText-style), and spec utilities.

Mesh axes:
  single-pod : ('data', 'model')                    16 x 16 = 256 chips
  multi-pod  : ('pod', 'data', 'model')             2 x 16 x 16 = 512 chips

Logical axes used by the model zoo:

  'batch'    activation batch                -> DP over ('pod','data')
  'embed'    d_model dim of weights          -> FSDP over ('pod','data') [train]
  'vocab'    embedding-table / logits vocab  -> 'model'
  'heads'    attention heads                 -> 'model'
  'kv_heads' kv heads (GQA)                  -> 'model' when divisible else None
  'mlp'      ffn hidden                      -> 'model'
  'experts'  MoE expert dim                  -> 'model'  (expert parallelism)
  'q_lora'/'kv_lora'  MLA latent dims        -> None (small, replicated)
  'layers'   scan dim of stacked weights     -> None
  'seq'      sequence dim of activations     -> None ('data' for long-decode
                                                distributed flash-decode)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import module as nnm

Rules = Dict[str, Any]


def make_rules(mesh: Mesh, *, mode: str = "train", cfg=None) -> Rules:
    """Sharding policy.

    mode='train'      FSDP (weights' embed dim over DP axes, ZeRO-3) + TP
                      over 'model' — the throughput-optimal policy when
                      every step touches all weights with large batches.
    mode='serve'      same layout (baseline; weights are re-gathered every
                      step — the measured collective bottleneck of the
                      baseline decode cells, EXPERIMENTS.md §Perf A0).
    mode='serve_2dtp' beyond-paper serving policy: NO data-axis dim on any
                      weight's contracting-with-x dim; instead weights are
                      2D-sharded over ('model' x 'data') on head/expert/ffn
                      and lora dims, so they stay RESIDENT and per-step
                      collectives are activation-sized (decode activations
                      are tiny).  See EXPERIMENTS.md §Perf A1.
    mode='dp'         pure data-parallel: small models (xlstm-350m) pay
                      more for FSDP/TP collectives than the weights are
                      worth; replicate weights, shard batch only.
                      See EXPERIMENTS.md §Perf C1.

    ``cfg`` (a ModelConfig) enables divisibility adjustment: any logical
    axis whose dimension does not divide by its mesh axis size falls back
    to replication (e.g. gemma3's 4 heads or granite's MQA kv=1 cannot
    shard over a 16-way 'model' axis)."""
    axes = mesh.axis_names
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    dp: Any = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    model_size = sizes.get("model", 1)
    rules: Rules = {
        "batch": dp,
        "embed": dp,  # ZeRO-3 / FSDP weight sharding
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "q_lora": None,
        "kv_lora": None,
        "layers": None,
        "seq": None,
        "act_embed": None,   # activation d_model dim
        "act_heads": "model",
        "cache_seq": None,   # 'model' for serve_2dtp distributed decode
        "expert_mlp": None,
    }
    if mode == "dp":
        rules.update({"embed": None, "vocab": None, "heads": None,
                      "kv_heads": None, "mlp": None, "experts": None,
                      "act_heads": None})
        return rules
    if mode == "tp":
        # TP without FSDP: small models whose weights fit replicated-over-
        # data; keeps model-axis compute sharding, drops the per-layer
        # weight re-gathers (EXPERIMENTS.md §Perf C3).
        rules["embed"] = None
    if mode == "serve_2dtp":
        data_ax = "data" if "data" in axes else None
        rules.update({
            "embed": None,                 # weights resident, not FSDP
            "q_lora": data_ax,             # MLA q path 2D: lora x heads
            "expert_mlp": data_ax,         # MoE experts 2D: E x F
            "mlp": (("model",) + ((data_ax,) if data_ax else ()))
            if cfg is None or not cfg.n_experts else "model",
            "cache_seq": "model",          # distributed flash-decode
        })
    if cfg is not None:
        def rule_size(axis):
            r = rules[axis]
            names = r if isinstance(r, tuple) else (r,) if r else ()
            n = 1
            for a in names:
                n *= sizes.get(a, 1)
            return n

        def fallback(axis, dim, downgrade=None):
            if rules[axis] and dim % rule_size(axis) != 0:
                rules[axis] = downgrade

        fallback("embed", cfg.d_model)
        fallback("vocab", cfg.vocab)
        fallback("heads", cfg.n_heads)
        fallback("kv_heads", cfg.n_kv_heads)
        if rules["heads"] is None:
            rules["act_heads"] = None
        mlp_dims = [d for d in (cfg.d_ff, cfg.d_inner if cfg.family in
                                ("hybrid", "ssm") else 0,
                                cfg.first_dense_d_ff,
                                cfg.n_shared_experts * cfg.moe_d_ff) if d]
        for d in mlp_dims:
            fallback("mlp", d, "model" if isinstance(rules["mlp"], tuple)
                     and d % model_size == 0 else None)
        if cfg.n_experts:
            fallback("experts", cfg.n_experts)
        if cfg.q_lora_rank:
            fallback("q_lora", cfg.q_lora_rank)
        if cfg.moe_d_ff:
            fallback("expert_mlp", cfg.moe_d_ff)
    return rules


def spec(axes: Tuple[Optional[str], ...], rules: Rules) -> PartitionSpec:
    return PartitionSpec(*[rules.get(a) if a is not None else None for a in axes])


def param_specs(defs, rules: Rules):
    """PartitionSpec tree for a P-def tree."""
    return nnm.map_defs(lambda _, p: spec(p.axes, rules), defs)


def param_shardings(defs, mesh: Mesh, rules: Rules):
    return nnm.map_defs(
        lambda _, p: NamedSharding(mesh, spec(p.axes, rules)), defs
    )


def logical_sharding(mesh: Mesh, rules: Rules, *axes: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, spec(tuple(axes), rules))


def with_constraint(x, rules: Rules, *axes: Optional[str]):
    """Sharding constraint by logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec(tuple(axes), rules))
    except (ValueError, RuntimeError):
        return x
