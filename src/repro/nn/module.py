"""Minimal functional module system.

Design: a *module* is a triple of pure functions over a config:

  - ``param_defs(cfg) -> tree[P]``   declarative parameter definitions
  - ``init(key, defs, dtype) -> tree[Array]``
  - ``apply(params, cfg, *inputs) -> outputs``

Parameter definitions carry *logical axis names* (``'embed'``, ``'heads'``,
``'mlp'`` ...) so the same model definition yields both the init shapes and
the GSPMD ``PartitionSpec`` tree via ``repro.nn.sharding``.  Keeping defs
declarative guarantees init / sharding / eval_shape never drift apart.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any  # nested dict


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter definition.

    shape : concrete shape
    axes  : logical axis name per dim (None = replicated / not sharded)
    init  : 'normal' | 'zeros' | 'ones' | 'embed' | 'fan_in'
    scale : stddev override (default: fan-in scaled)
    dtype : override of the module-wide param dtype
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"
    scale: Optional[float] = None
    dtype: Optional[Any] = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")


def _path_key(key: jax.Array, path: str) -> jax.Array:
    # Deterministic, order-independent per-parameter key derivation.
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def _init_one(key: jax.Array, p: P, default_dtype) -> jax.Array:
    dtype = p.dtype or default_dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape) * std).astype(dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 1.0
        return (jax.random.normal(key, p.shape) * std).astype(dtype)
    if p.init == "fan_in":
        # fan-in = product of all dims except the last (output) dim.
        fan_in = max(1, int(np.prod(p.shape[:-1])))
        std = p.scale if p.scale is not None else fan_in ** -0.5
        return (jax.random.normal(key, p.shape) * std).astype(dtype)
    raise ValueError(f"unknown init {p.init}")


def is_def(x) -> bool:
    return isinstance(x, P)


def map_defs(fn: Callable[[str, P], Any], defs: Tree, prefix: str = "") -> Tree:
    """Map over a tree of P leaves, passing the string path to ``fn``."""
    if is_def(defs):
        return fn(prefix, defs)
    if isinstance(defs, dict):
        return {k: map_defs(fn, v, f"{prefix}/{k}") for k, v in defs.items()}
    if isinstance(defs, (list, tuple)):
        t = type(defs)
        return t(map_defs(fn, v, f"{prefix}/{i}") for i, v in enumerate(defs))
    raise TypeError(f"unexpected node {type(defs)} at {prefix}")


def init_params(key: jax.Array, defs: Tree, param_dtype=jnp.float32) -> Tree:
    return map_defs(lambda path, p: _init_one(_path_key(key, path), p, param_dtype), defs)


def shapes(defs: Tree, param_dtype=jnp.float32) -> Tree:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return map_defs(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, p.dtype or param_dtype), defs
    )


def logical_axes(defs: Tree) -> Tree:
    return map_defs(lambda _, p: p.axes, defs)


def count_params(defs: Tree) -> int:
    n = [0]

    def add(_, p):
        n[0] += int(np.prod(p.shape))
        return None

    map_defs(add, defs)
    return n[0]


def stack_defs(defs: Tree, n: int, axis_name: Optional[str] = None) -> Tree:
    """Prepend a stacking dim of size n (for scan-over-layers weights)."""
    return map_defs(
        lambda _, p: dataclasses.replace(
            p, shape=(n,) + p.shape, axes=(axis_name,) + p.axes
        ),
        defs,
    )
