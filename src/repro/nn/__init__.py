from . import layers, module, sharding
from .module import P, init_params, logical_axes, count_params, shapes, stack_defs

__all__ = [
    "layers", "module", "sharding", "P",
    "init_params", "logical_axes", "count_params", "shapes", "stack_defs",
]
