"""deepseek-v3-671b — the paper's Table-1 instantiation (bonus config; not
part of the assigned 40-cell matrix, used by the hwmodel benchmarks and as
an extra-scale dry-run target).
61L d_model=7168 128H, MLA q_lora=1536 kv_lora=512, MoE 1 shared + 256
routed top-8, d_ff(expert)=2048, first 3 layers dense (d_ff=18432).
[arXiv:2412.19437]
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    first_dense_layers=3, first_dense_d_ff=18432,
    max_seq=524_288 + 8,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=256,
    attn_kind="mla", q_lora_rank=48, kv_lora_rank=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, moe_d_ff=32, n_shared_experts=1,
    first_dense_layers=3, first_dense_d_ff=128,
    max_seq=128, remat=False,
)

SKIP_SHAPES: dict = {}
