"""starcoder2-7b  [dense] — GQA, RoPE, code model.
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173; hf]

GELU 2-matrix MLP (starcoder2 uses gelu; matches the 7B count).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152, mlp_kind="gelu",
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=4, d_model=72, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, mlp_kind="gelu",
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention (GQA KV cache, no sub-quadratic mechanism)",
}
