"""deepseek-v2-236b  [moe] — the paper's native architecture (MLA + MoE).
60L d_model=5120 128H d_ff=1536(expert) vocab=102400
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128
MoE: 2 shared + 160 routed experts, top-6; first layer dense (d_ff=12288)
[arXiv:2405.04434; hf]

This is the hillclimb target for the paper's technique: both MLA decode
schemes (rc/ru/seq/naive) are runtime-selectable on this config.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=160, top_k=6, moe_d_ff=1536, n_shared_experts=2,
    first_dense_layers=1, first_dense_d_ff=12288,
    max_seq=524_288 + 8,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=256,
    attn_kind="mla", q_lora_rank=48, kv_lora_rank=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, moe_d_ff=32, n_shared_experts=2,
    first_dense_layers=1, first_dense_d_ff=128,
    max_seq=128, remat=False,
)

SKIP_SHAPES: dict = {}  # MLA latent cache (576 B/token/layer): 500k decode
# is exactly the paper's headline benefit — runs.
