"""Config registry: ``get(arch_id)`` -> (FULL, SMOKE, SKIP_SHAPES)."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.common import ModelConfig
from .shapes import SHAPES, Shape

_MODULES: Dict[str, str] = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma3-1b": "gemma3_1b",
    "granite-34b": "granite_34b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "starcoder2-7b": "starcoder2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "xlstm-350m": "xlstm_350m",
    "whisper-medium": "whisper_medium",
    "internvl2-26b": "internvl2_26b",
    # bonus (paper Table 1 dims; not in the assigned 40-cell matrix):
    "deepseek-v3-671b": "deepseek_v3_671b",
}

ARCHS = [a for a in _MODULES if a != "deepseek-v3-671b"]


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def full(arch: str) -> ModelConfig:
    return _load(arch).FULL


def smoke(arch: str) -> ModelConfig:
    return _load(arch).SMOKE


def skip_shapes(arch: str) -> dict:
    return _load(arch).SKIP_SHAPES


def cells(include_skipped: bool = False):
    """All (arch, shape) cells of the assigned matrix."""
    out = []
    for a in ARCHS:
        skips = skip_shapes(a)
        for s in SHAPES.values():
            if include_skipped or s.name not in skips:
                out.append((a, s.name))
    return out
