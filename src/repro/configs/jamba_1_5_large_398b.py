"""jamba-1.5-large-398b  [hybrid]  — Mamba+attn 1:7 interleave, MoE 16e top-2.
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536
[arXiv:2403.19887; hf]

Jamba period = 8 layers: attention at offset 3, Mamba elsewhere; MoE on
every 2nd layer (the rest dense MLP).  72 layers = 9 periods (scanned).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    attn_period=8, attn_offset=3, moe_period=2,
    n_experts=16, top_k=2, moe_d_ff=24576,
    d_state=16, conv_width=4, expand=2,
    max_seq=524_288 + 8,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=16, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    attn_period=8, attn_offset=3, moe_period=2,
    n_experts=4, top_k=2, moe_d_ff=64,
    d_state=8, conv_width=4, expand=2,
    max_seq=128, remat=False,
)

SKIP_SHAPES: dict = {}  # hybrid SSM: O(1) mamba state + bounded GQA layers
