"""granite-34b  [dense] — code model, MQA.
88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]

GELU 2-matrix MLP (matches the 34B param count; 3-matrix SwiGLU at this
d_ff would be 46B).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, mlp_kind="gelu",
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=256, mlp_kind="gelu",
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention (MQA, no window/latent/SSM structure)",
}
