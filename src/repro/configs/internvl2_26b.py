"""internvl2-26b  [vlm] — InternViT frontend STUB + InternLM2-20B backbone.
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]

``input_specs`` supplies 1024 precomputed patch embeddings prepended to
(seq - 1024) text tokens for train/prefill; decode shapes are text-only
with the image prefix already in cache (DESIGN.md §6).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92553,
    n_patches=1024,
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    n_patches=8,
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention backbone (GQA KV cache, no sub-quadratic mechanism)",
}
