"""whisper-medium  [audio] — encoder-decoder, conv frontend STUB.
24L(dec)+24L(enc) d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]

``input_specs`` provides precomputed frame embeddings (B, 1500, d_model) in
place of the log-mel conv stack.  Decoder positions beyond the trained 448
are a dry-run formality (DESIGN.md §6).  Cross-attn K/V computed once at
prefill = the extreme 'reuse' point of the paper's rc/ru spectrum.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865, mlp_kind="gelu",
    n_enc_layers=24, n_frames=1500,
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, mlp_kind="gelu",
    n_enc_layers=2, n_frames=16,
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention decoder (dense KV cache; trained ctx 448)",
}
