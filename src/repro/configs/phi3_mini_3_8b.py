"""phi3-mini-3.8b  [dense] — RoPE SwiGLU, full MHA (kv=32).
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[arXiv:2404.14219; unverified]
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention (dense MHA KV cache, no sub-quadratic mechanism)",
}
