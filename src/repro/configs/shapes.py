"""The assigned input-shape set (identical for every LM arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), NOT ``train_step``; ``long_500k`` requires a
sub-quadratic mechanism and is skipped for pure full-attention archs
(configs declare SKIP_SHAPES with a reason — see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}
