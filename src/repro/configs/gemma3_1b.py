"""gemma3-1b  [dense] — 5:1 local:global attention, 128k context.
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 head_dim=256
[hf:google/gemma-3-1b-pt; unverified]

local_global_period=6: five sliding-window (512) layers then one global
layer (rope base 1M).  long_500k runs: 5/6 of the cache is bounded at the
window; the global layers use the seq-sharded distributed decode path.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    local_global_period=6, window=512, global_rope_base=1_000_000.0,
    embed_scale=True,
    max_seq=524_288 + 8,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=12, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=256,
    local_global_period=6, window=8, global_rope_base=1_000_000.0,
    embed_scale=True,
    max_seq=128, remat=False,
)

SKIP_SHAPES: dict = {}  # 5/6 layers window-bounded; globals seq-sharded
