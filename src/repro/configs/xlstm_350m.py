"""xlstm-350m  [ssm] — sLSTM + mLSTM blocks (attention-free, O(1) state).
24L d_model=1024 4H d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]

7:1 mLSTM:sLSTM ratio (one sLSTM per 8 layers).  No KV cache of any kind —
the O(1)-state limit point of the paper's OI analysis (DESIGN.md §5).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8, expand=2,
    max_seq=524_288 + 8,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=256,
    slstm_every=8, expand=2,
    max_seq=128, remat=False,
)

SKIP_SHAPES: dict = {}  # attention-free: all shapes run
