"""granite-moe-1b-a400m  [moe] — 32 experts top-8, every layer MoE.
24L d_model=1024 16H (GQA kv=8) d_ff=512(expert) vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=32, top_k=8, moe_d_ff=512,
    max_seq=32_768 + 8,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=256,
    n_experts=8, top_k=4, moe_d_ff=32,
    max_seq=128, remat=False,
)

SKIP_SHAPES = {
    "long_500k": "pure full-attention (GQA KV cache, no sub-quadratic mechanism)",
}
