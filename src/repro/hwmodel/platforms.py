"""Hardware design points.

The dotted-line platforms from the paper's Figs 4-5 plus the deployment
target (TPU v5e) and other common accelerators.  Numbers are public peak
specs; the ridge OI (peak_flops / hbm_bw) is what the paper calls the
"roofline corner".
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from ..core.schemes import PlatformPoint


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """E_op derived from TOPS/W; E_DRAM,bit is a DRAM-technology constant."""
    tops_per_w: float            # on-chip efficiency
    e_dram_bit_pj: float = 8.0   # paper's constant (pJ/bit)

    @property
    def e_op_pj(self) -> float:  # pJ per operation
        return 1.0 / self.tops_per_w

    def energy_pj(self, flops: float, dram_bytes: float) -> float:
        return flops * self.e_op_pj + dram_bytes * 8.0 * self.e_dram_bit_pj


PLATFORMS: Dict[str, PlatformPoint] = {
    # name                      peak FLOP/s     DRAM B/s
    "edge_tpu": PlatformPoint("edge_tpu", 4.0e12, 8.0e9),          # Coral: 4 TOPS, LPDDR4
    "a17_pro": PlatformPoint("a17_pro", 35.0e12, 51.2e9),          # ANE 35 TOPS, LPDDR5
    "jetson_orin": PlatformPoint("jetson_orin", 170.0e12, 204.8e9),
    "tpu_v5e": PlatformPoint("tpu_v5e", 197.0e12, 819.0e9),        # deployment target
    "tpu_v4": PlatformPoint("tpu_v4", 275.0e12, 1228.0e9),
    "a100": PlatformPoint("a100", 312.0e12, 2039.0e9),
    "h100": PlatformPoint("h100", 989.0e12, 3352.0e9),
}

# TPU v5e chip + pod constants used by the roofline report (EXPERIMENTS.md).
TPU_V5E_PEAK_FLOPS = 197.0e12      # bf16
TPU_V5E_HBM_BW = 819.0e9           # B/s
TPU_V5E_ICI_BW = 50.0e9            # B/s per link (~3 usable links/chip on 2D torus)
TPU_V5E_HBM_GB = 16.0
