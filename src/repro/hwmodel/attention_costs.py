"""Closed-form operation / off-chip-byte counts for the paper's four
attention methods (MLA_rc, MLA_ru, MHA_l, MHA_s) plus the 'seq' and
'naive' orderings — the analytical backbone of Figs 2-4.

Conventions (matching the letter):
  * "operations" = FLOPs = 2 x MACs.
  * Off-chip accesses count weights (once per step, batch-shared), the
    KV / latent cache (read once, new entry written), and optionally
    activations in/out (``include_io``).  Intermediates are assumed to
    stay on-chip (the paper's fused-execution assumption; realized on TPU
    by the Pallas kernels / XLA fusion — see kernels/mla_decode.py).
  * ``rope=False`` reproduces the paper exactly (it omits RoPE); the
    framework default is rope=True for deployment honesty.
  * Softmax is neglected in Fig 3 (paper does the same) and modeled in
    ``roofline.py`` via ``softmax_flops`` (Stream models it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..core.mla import MLAConfig


@dataclasses.dataclass(frozen=True)
class MHAConfig:
    d_model: int
    n_heads: int
    qk_dim: int
    v_dim: int

    def param_count(self) -> int:
        return self.n_heads * self.d_model * (2 * self.qk_dim + self.v_dim) \
            + self.n_heads * self.v_dim * self.d_model


# DeepSeek-V3 instantiations (paper Table 1)
DSV3_MLA = MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                     kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                     v_head_dim=128)
MHA_L = MHAConfig(d_model=7168, n_heads=128, qk_dim=128, v_dim=128)
MHA_S = MHAConfig(d_model=4363, n_heads=128, qk_dim=77, v_dim=77)


@dataclasses.dataclass
class Cost:
    flops: float
    bytes: float
    breakdown: Dict[str, float]

    @property
    def oi(self) -> float:
        return self.flops / max(self.bytes, 1.0)


# ------------------------------------------------------------------ MLA ----


def _dims(cfg: MLAConfig, rope: bool):
    dr = cfg.qk_rope_dim if rope else 0
    return cfg.d_model, cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank, \
        cfg.qk_nope_dim, dr, cfg.v_head_dim


def mla_decode_cost(cfg: MLAConfig, *, scheme: str, cache_len: int,
                    batch: int = 1, dtype_bytes: int = 2, rope: bool = False,
                    include_io: bool = False, paged_block: int = 0,
                    table_entry_bytes: int = 4, dp_shards: int = 1,
                    cache_dtype_bytes: Optional[float] = None) -> Cost:
    """One decode step of one MLA layer. ``cache_len`` = L (incl. new token).

    ``paged_block > 0`` models the paged latent cache: reads happen in
    whole blocks (internal fragmentation rounds L up to a block multiple)
    and each step additionally streams the per-request block tables
    (ceil(L/bs) int32 entries per request).  Keeps the roofline honest for
    the continuous-batching runtime (runtime.engine).

    ``dp_shards > 1`` returns the PER-DEVICE cost of data-parallel serving
    (runtime.steps: batch/table/length rows sharded over the DP axes):
    every batch-proportional term — cache read/write, block-table
    traffic, per-token projections and scores — shrinks to the local
    batch ceil(B / dp_shards), while the WEIGHT bytes are unchanged (each
    device still streams the full weight set per step; the pool is
    replicated, but a device only reads the blocks its local rows
    reference).  This is the scale-out shape of the paper's bandwidth
    argument: DP scales the served batch while per-device cache traffic
    stays flat.

    ``cache_dtype_bytes`` overrides the per-ELEMENT byte width of the
    latent-cache terms only (read + write): a quantized {int8|fp8} pool
    stores 1-byte payloads plus two per-row f32 scales, which
    core.cache.cache_element_bytes folds into a fractional width.
    Weights, activations and spills stay at ``dtype_bytes`` — only the
    cache streams shrink, which is exactly the crossover shift
    auto_dispatch must see."""
    D, H, Q, K, dn, dr, dv = _dims(cfg, rope)
    if dp_shards < 1:
        raise ValueError(f"dp_shards must be >= 1, got {dp_shards}")
    B, L, w = -(-batch // dp_shards), cache_len, dtype_bytes
    cw = dtype_bytes if cache_dtype_bytes is None else cache_dtype_bytes
    fl: Dict[str, float] = {}
    by: Dict[str, float] = {}

    # ---- common projections (per token) --------------------------------
    fl["q_down"] = 2 * B * D * Q
    fl["kv_down"] = 2 * B * D * (K + dr)
    fl["attn_scores"] = 2 * B * H * L * (K + dr)
    fl["attn_out"] = 2 * B * H * L * K
    fl["v_up"] = 2 * B * H * K * dv
    fl["o_proj"] = 2 * B * H * dv * D
    by["w_common"] = (D * Q + D * (K + dr) + K * H * dv + H * dv * D) * w
    by["cache_read"] = B * L * (K + dr) * cw
    by["cache_write"] = B * (K + dr) * cw
    if paged_block:
        n_blk = -(-L // paged_block)
        by["cache_read"] = B * n_blk * paged_block * (K + dr) * cw
        by["block_table"] = B * n_blk * table_entry_bytes

    # ---- scheme-specific nope-query transform --------------------------
    if scheme == "seq":                       # 1->2->3, factored
        fl["q_up"] = 2 * B * Q * H * (dn + dr)
        fl["q_latent"] = 2 * B * H * dn * K
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
    elif scheme == "rc":                      # 2->1->3, recompute absorb
        fl["q_up_rope"] = 2 * B * Q * H * dr
        fl["absorb_recompute"] = 2 * H * Q * dn * K  # batch-shared!
        fl["q_latent"] = 2 * B * H * Q * K
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
    elif scheme == "ru":                      # precomputed absorb, streamed
        fl["q_up_rope"] = 2 * B * Q * H * dr
        fl["q_latent"] = 2 * B * H * Q * K
        by["w_scheme"] = (H * Q * K + Q * H * dr) * w
    elif scheme == "naive":                   # 1->3->2, up-project cache
        fl["q_up"] = 2 * B * Q * H * (dn + dr)
        fl["k_up"] = 2 * B * L * K * H * dn
        fl["v_up_cache"] = 2 * B * L * K * H * dv
        # attention runs in the full space instead of latent:
        fl["attn_scores"] = 2 * B * H * L * (dn + dr)
        fl["attn_out"] = 2 * B * H * L * dv
        fl["v_up"] = 0.0
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
        # up-projected K/V do not fit on-chip for large L: spilled + re-read
        by["kv_spill"] = 2 * B * L * H * (dn + dr + dv) * w
    else:
        raise ValueError(scheme)

    if include_io:
        by["io"] = 2 * B * D * w
    return Cost(sum(fl.values()), sum(by.values()), {**fl, **{f"B:{k}": v for k, v in by.items()}})


def rescale_multiplies(cfg: MLAConfig, *, cache_len: int, batch: int = 1,
                       paged_block: int = 1, rescale: str = "exp_add",
                       rope: bool = True) -> float:
    """Modeled per-layer count of online-softmax RESCALE multiplies in one
    decode step of the paged kernel (kernels.mla_decode): every block-tile
    update corrects the running accumulator (H x kv_lora) and denominator
    (H) by exp(m_prev - m_new).

      'mul'     — the textbook FlashAttention correction: one f32 multiply
                  per corrected element, B * n_tiles * H * (kv_lora + 1).
      'exp_add' — AMLA-style exponent-addition (arXiv:2509.25224): m is
                  quantized to integers in log2 space, so the correction
                  2^{-d} lands as an integer add into the f32 exponent
                  field — zero multiplies on the rescale path (the
                  per-element cost degrades to bitcast + integer add,
                  which shares no port with the MXU/VPU multiplier).

    This isolates the term the AMLA trick deletes; it is NOT folded into
    :func:`mla_decode_cost` (which counts MAC FLOPs only, per the paper's
    convention) — tests assert the modeled count drops to zero."""
    if rescale not in ("exp_add", "mul"):
        raise ValueError(f"unknown rescale {rescale!r}")
    if rescale == "exp_add":
        return 0.0
    _, H, _, K, _, _, _ = _dims(cfg, rope)
    n_tiles = -(-cache_len // max(paged_block, 1))
    return float(batch * n_tiles * H * (K + 1))


def mla_verify_cost(cfg: MLAConfig, *, scheme: str, cache_len: int,
                    k: int, batch: int = 1, dtype_bytes: int = 2,
                    rope: bool = False, include_io: bool = False,
                    paged_block: int = 0, table_entry_bytes: int = 4,
                    dp_shards: int = 1,
                    cache_dtype_bytes: Optional[float] = None) -> Cost:
    """One SPECULATIVE-DECODE verify step of one MLA layer: q = k + 1
    query positions (the last sampled token + k draft tokens) scored
    against the same resident cache in one forward
    (runtime.steps.make_verify_step — the chunked-prefill machinery with
    chunk = k + 1).

    The amortization speculative decoding exists for, in MLA terms: the
    latent-cache read and every weight stream are paid ONCE for the whole
    window instead of once per token, while the per-token projections and
    scores scale with q.  ``cache_len`` counts the resident tokens BEFORE
    the window (query j attends cache_len + j + 1 positions); k = 0
    degrades to :func:`mla_decode_cost` up to the in-window causal terms.
    ``paged_block`` / ``dp_shards`` behave exactly as in
    :func:`mla_decode_cost` (whole-block reads + table traffic; per-device
    batch under data-parallel serving).  See also
    :func:`spec_break_even` for the accepted-length break-even this
    implies."""
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    D, H, Q, K, dn, dr, dv = _dims(cfg, rope)
    if dp_shards < 1:
        raise ValueError(f"dp_shards must be >= 1, got {dp_shards}")
    B, w, q = -(-batch // dp_shards), dtype_bytes, k + 1
    cw = dtype_bytes if cache_dtype_bytes is None else cache_dtype_bytes
    # mean attended length over the in-window causal ramp
    Lbar = cache_len + (q + 1) / 2
    L_end = cache_len + q                   # resident extent after the step
    fl: Dict[str, float] = {}
    by: Dict[str, float] = {}

    # ---- per-token projections: scale with the window ------------------
    fl["q_down"] = 2 * B * q * D * Q
    fl["kv_down"] = 2 * B * q * D * (K + dr)
    fl["attn_scores"] = 2 * B * H * q * Lbar * (K + dr)
    fl["attn_out"] = 2 * B * H * q * Lbar * K
    fl["v_up"] = 2 * B * q * H * K * dv
    fl["o_proj"] = 2 * B * q * H * dv * D
    # ---- batch- AND window-shared streams: paid once per round ----------
    by["w_common"] = (D * Q + D * (K + dr) + K * H * dv + H * dv * D) * w
    by["cache_read"] = B * cache_len * (K + dr) * cw
    by["cache_write"] = B * q * (K + dr) * cw
    if paged_block:
        n_blk = -(-L_end // paged_block)
        by["cache_read"] = B * n_blk * paged_block * (K + dr) * cw
        by["block_table"] = B * n_blk * table_entry_bytes

    if scheme == "seq":
        fl["q_up"] = 2 * B * q * Q * H * (dn + dr)
        fl["q_latent"] = 2 * B * q * H * dn * K
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
    elif scheme == "rc":
        fl["q_up_rope"] = 2 * B * q * Q * H * dr
        fl["absorb_recompute"] = 2 * H * Q * dn * K   # batch/window-shared
        fl["q_latent"] = 2 * B * q * H * Q * K
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
    elif scheme == "ru":
        fl["q_up_rope"] = 2 * B * q * Q * H * dr
        fl["q_latent"] = 2 * B * q * H * Q * K
        by["w_scheme"] = (H * Q * K + Q * H * dr) * w
    elif scheme == "naive":
        fl["q_up"] = 2 * B * q * Q * H * (dn + dr)
        fl["k_up"] = 2 * B * Lbar * K * H * dn
        fl["v_up_cache"] = 2 * B * Lbar * K * H * dv
        fl["attn_scores"] = 2 * B * H * q * Lbar * (dn + dr)
        fl["attn_out"] = 2 * B * H * q * Lbar * dv
        fl["v_up"] = 0.0
        by["w_scheme"] = (Q * H * (dn + dr) + K * H * dn) * w
        by["kv_spill"] = 2 * B * Lbar * H * (dn + dr + dv) * w
    else:
        raise ValueError(scheme)

    if include_io:
        by["io"] = 2 * B * q * D * w
    return Cost(sum(fl.values()), sum(by.values()),
                {**fl, **{f"B:{n}": v for n, v in by.items()}})


def spec_break_even(cfg: MLAConfig, *, scheme: str, cache_len: int, k: int,
                    batch: int = 1, dtype_bytes: int = 2,
                    paged_block: int = 0, dp_shards: int = 1,
                    draft_bytes_frac: float = 0.0,
                    cache_dtype_bytes: Optional[float] = None) -> Dict[str, float]:
    """Expected-accepted-length break-even of speculative decoding, on
    the bandwidth axis (the regime the paper places large-batch MLA
    decode in): one verify round emits E in [1, k+1] tokens for one
    verify step's bytes (+ the draft's, as ``draft_bytes_frac`` of a
    plain decode step per drafted token).  Spec wins when

        E  >  (verify.bytes + k * draft_frac * decode.bytes) / decode.bytes

    Returns the break-even E*, the per-emitted-token byte ratios at the
    extremes, and the raw byte counts — bench_serving reports E* next to
    the measured mean accepted length so the runtime row and the model
    agree on when drafting pays."""
    verify = mla_verify_cost(cfg, scheme=scheme, cache_len=cache_len, k=k,
                             batch=batch, dtype_bytes=dtype_bytes,
                             paged_block=paged_block, dp_shards=dp_shards,
                             cache_dtype_bytes=cache_dtype_bytes)
    decode = mla_decode_cost(cfg, scheme=scheme, cache_len=cache_len,
                             batch=batch, dtype_bytes=dtype_bytes,
                             paged_block=paged_block, dp_shards=dp_shards,
                             cache_dtype_bytes=cache_dtype_bytes)
    round_bytes = verify.bytes + k * draft_bytes_frac * decode.bytes
    return {
        "verify_bytes": verify.bytes,
        "decode_bytes": decode.bytes,
        "round_bytes": round_bytes,
        "break_even_emitted": round_bytes / decode.bytes,
        "bytes_per_token_best": round_bytes / (k + 1),
        "bytes_per_token_worst": round_bytes,
        "amortization_at_full_accept": decode.bytes * (k + 1) / round_bytes,
    }


def mla_prefill_cost(cfg: MLAConfig, *, seq_len: int, batch: int = 1,
                     dtype_bytes: int = 2, rope: bool = False, causal: bool = True,
                     include_io: bool = True, cached_prefix: int = 0,
                     cache_dtype_bytes: Optional[float] = None) -> Cost:
    """Prefill of an L-token prompt; ``cached_prefix = P`` tokens are
    served by the radix prefix cache (runtime.prefix_cache): only the
    Ls = L - P suffix tokens are projected / written, the suffix queries
    still attend the FULL prompt (the shared prefix's latents are READ
    from the pool instead of recomputed).  P = 0 reproduces the plain
    prefill exactly; the causal score/PV term generalizes to the exact
    pair fraction (L^2 - P^2) / 2."""
    D, H, Q, K, dn, dr, dv = _dims(cfg, rope)
    B, L, w = batch, seq_len, dtype_bytes
    cw = dtype_bytes if cache_dtype_bytes is None else cache_dtype_bytes
    P = cached_prefix
    if not 0 <= P < max(L, 1):
        raise ValueError(f"cached_prefix {P} out of range for seq_len {L}")
    Ls = L - P
    att = 0.5 if causal else 1.0
    # query x key position pairs inside the causal mask, suffix rows only:
    # sum_{i=P..L-1}(i+1) ~ (L^2 - P^2)*att  (matches the paper's L^2/2
    # convention at P=0)
    pairs = (L * L - P * P) * att if causal else Ls * L
    fl = {
        "q_down": 2 * B * Ls * D * Q,
        "q_up": 2 * B * Ls * Q * H * (dn + dr),
        "kv_down": 2 * B * Ls * D * (K + dr),
        "k_up": 2 * B * Ls * K * H * dn,
        "v_up": 2 * B * Ls * K * H * dv,
        "attn_scores": 2 * B * H * pairs * (dn + dr),
        "attn_out": 2 * B * H * pairs * dv,
        "o_proj": 2 * B * Ls * H * dv * D,
    }
    by = {
        "weights": (D * Q + Q * H * (dn + dr) + D * (K + dr) + K * H * dn
                    + K * H * dv + H * dv * D) * w,
        "cache_write": B * Ls * (K + dr) * cw,
    }
    if P:
        # the shared prefix's compact latents stream in from the pool
        by["prefix_read"] = B * P * (K + dr) * cw
    if include_io:
        by["io"] = 2 * B * Ls * D * w
    return Cost(sum(fl.values()), sum(by.values()), {**fl, **{f"B:{k}": v for k, v in by.items()}})


def mla_prefill_chunk_cost(cfg: MLAConfig, *, seq_len: int, chunk: int,
                           paged_block: int, batch: int = 1,
                           dtype_bytes: int = 2, rope: bool = False,
                           cached_prefix: int = 0, impl: str = "pallas",
                           include_io: bool = True,
                           table_entry_bytes: int = 4,
                           cache_dtype_bytes: Optional[float] = None) -> Cost:
    """Chunked PAGED prefill of an L-token prompt, C tokens per chunk,
    over a block pool with ``paged_block``-token blocks.

    ``impl`` models the two chunk-attention paths of
    core.mla.mla_prefill_chunk_paged:

      'gather' — the reference path: every chunk MATERIALIZES the
        contiguous (B, W) block-table view in HBM (pool gather read +
        view write + attention re-read, W = the table extent rounded to
        whole blocks) and computes scores over the full view width —
        per-chunk bytes AND FLOPs scale with W regardless of how little
        of the prompt is resident yet.
      'pallas' — the kernel (kernels.mla_prefill): walks the block table
        in place, streaming only the blocks at-or-before the chunk's
        last valid position, once, plus the table entries themselves.
        No view is ever written; this is what restores the arithmetic
        intensity the paper's roofline assigns the compute-bound prefill
        phase (scores stay on-chip, the pool streams HBM->VMEM once).

    Weights are re-streamed once per chunk step (each chunk is its own
    jitted step).  ``cached_prefix = P`` tokens ride the radix prefix
    cache: only the suffix is projected/written, but every chunk still
    attends the resident prefix through the table.
    """
    if impl not in ("gather", "pallas"):
        raise ValueError(f"unknown impl {impl!r}")
    if chunk < 1 or paged_block < 1:
        raise ValueError("chunk and paged_block must be >= 1")
    D, H, Q, K, dn, dr, dv = _dims(cfg, rope)
    B, L, w, P, C, bs = batch, seq_len, dtype_bytes, cached_prefix, chunk, \
        paged_block
    cw = dtype_bytes if cache_dtype_bytes is None else cache_dtype_bytes
    if not 0 <= P < max(L, 1):
        raise ValueError(f"cached_prefix {P} out of range for seq_len {L}")
    Ls = L - P
    n_chunks = -(-Ls // C)
    # per-suffix-token projections (identical across impls; the 'seq'
    # absorption: q_nope -> latent via W_uk, PV output via W_uv)
    fl: Dict[str, float] = {
        "q_down": 2 * B * Ls * D * Q,
        "q_up": 2 * B * Ls * Q * H * (dn + dr),
        "q_latent": 2 * B * Ls * H * dn * K,
        "kv_down": 2 * B * Ls * D * (K + dr),
        "v_up": 2 * B * Ls * H * K * dv,
        "o_proj": 2 * B * Ls * H * dv * D,
    }
    w_bytes = (D * Q + Q * H * (dn + dr) + D * (K + dr) + K * H * dn
               + K * H * dv + H * dv * D) * w
    by: Dict[str, float] = {
        "weights": w_bytes * n_chunks,      # re-streamed every chunk step
        "cache_write": B * Ls * (K + dr) * cw,
    }
    W = -(-L // bs) * bs                    # table extent, whole blocks
    fl_attn = rd_pool = rd_table = view_bytes = 0.0
    for k in range(n_chunks):
        c_k = min(C, Ls - k * C)            # valid rows this chunk
        end_k = P + k * C + c_k             # newest attendable position + 1
        ext_k = -(-end_k // bs) * bs        # resident extent, whole blocks
        if impl == "pallas":
            fl_attn += 2 * B * H * c_k * ext_k * ((K + dr) + K)
            rd_pool += B * ext_k * (K + dr) * cw
            rd_table += B * (ext_k // bs) * table_entry_bytes
        else:
            # scores/PV run over the FULL gathered view width W (masked
            # entries are still computed), and the view round-trips HBM:
            # pool gather read (cache width) + dequantized f32 view
            # write + attention re-read (compute width — the gather path
            # materializes the view in f32/compute dtype, not int8).
            fl_attn += 2 * B * H * c_k * W * ((K + dr) + K)
            rd_pool += B * W * (K + dr) * cw
            view_bytes += 2 * B * W * (K + dr) * w
    fl["attn_scores_pv"] = fl_attn
    by["cache_read"] = rd_pool
    if impl == "pallas":
        by["block_table"] = rd_table
    else:
        by["gather_materialize"] = view_bytes
    if include_io:
        by["io"] = 2 * B * Ls * D * w
    return Cost(sum(fl.values()), sum(by.values()),
                {**fl, **{f"B:{k}": v for k, v in by.items()}})


def prefix_hit_savings(cfg: MLAConfig, *, seq_len: int, cached_prefix: int,
                       batch: int = 1, dtype_bytes: int = 2,
                       rope: bool = False) -> Dict[str, float]:
    """FLOPs / off-chip bytes a prefix-cache hit saves on one prefill,
    total and per shared token — the analytical counterpart of the
    runtime's hit-rate metric (bench_serving reports both).  The decode
    phase is unchanged by sharing (same L per request); the win is the
    prompt recompute + re-store that never happens, which is what moves
    TTFT (see core.schemes.prefill_time)."""
    base = mla_prefill_cost(cfg, seq_len=seq_len, batch=batch,
                            dtype_bytes=dtype_bytes, rope=rope)
    hit = mla_prefill_cost(cfg, seq_len=seq_len, batch=batch,
                           dtype_bytes=dtype_bytes, rope=rope,
                           cached_prefix=cached_prefix)
    P = max(cached_prefix, 1)
    return {
        "flops_saved": base.flops - hit.flops,
        "bytes_saved": base.bytes - hit.bytes,
        "flops_saved_per_token": (base.flops - hit.flops) / P,
        "bytes_saved_per_token": (base.bytes - hit.bytes) / P,
        "flops_frac": 1.0 - hit.flops / max(base.flops, 1.0),
        "bytes_frac": 1.0 - hit.bytes / max(base.bytes, 1.0),
    }


# ------------------------------------------------------------------ MHA ----


def mha_decode_cost(cfg: MHAConfig, *, cache_len: int, batch: int = 1,
                    dtype_bytes: int = 2, include_io: bool = False) -> Cost:
    D, H, dq, dv = cfg.d_model, cfg.n_heads, cfg.qk_dim, cfg.v_dim
    B, L, w = batch, cache_len, dtype_bytes
    fl = {
        "qkv_proj": 2 * B * D * H * (2 * dq + dv),
        "attn_scores": 2 * B * H * L * dq,
        "attn_out": 2 * B * H * L * dv,
        "o_proj": 2 * B * H * dv * D,
    }
    by = {
        "weights": (D * H * (2 * dq + dv) + H * dv * D) * w,
        "cache_read": B * L * H * (dq + dv) * w,
        "cache_write": B * H * (dq + dv) * w,
    }
    if include_io:
        by["io"] = 2 * B * D * w
    return Cost(sum(fl.values()), sum(by.values()), {**fl, **{f"B:{k}": v for k, v in by.items()}})


def mha_prefill_cost(cfg: MHAConfig, *, seq_len: int, batch: int = 1,
                     dtype_bytes: int = 2, causal: bool = True,
                     include_io: bool = True) -> Cost:
    D, H, dq, dv = cfg.d_model, cfg.n_heads, cfg.qk_dim, cfg.v_dim
    B, L, w = batch, seq_len, dtype_bytes
    att = 0.5 if causal else 1.0
    fl = {
        "qkv_proj": 2 * B * L * D * H * (2 * dq + dv),
        "attn_scores": 2 * B * H * L * L * dq * att,
        "attn_out": 2 * B * H * L * L * dv * att,
        "o_proj": 2 * B * L * H * dv * D,
    }
    by = {
        "weights": (D * H * (2 * dq + dv) + H * dv * D) * w,
        "cache_write": B * L * H * (dq + dv) * w,
    }
    if include_io:
        by["io"] = 2 * B * L * D * w
    return Cost(sum(fl.values()), sum(by.values()), {**fl, **{f"B:{k}": v for k, v in by.items()}})


# ------------------------------------------------- Fig 2: ordering study ----


def score_chain_ops(cfg: MLAConfig, order: str, cache_len: int,
                    batch: int = 1, rope: bool = False) -> float:
    """FLOPs of  Q_l . W_up^Q . W_up^{K,T} . C^T  under a given product
    order (Fig 2).  Orders: '123' left-to-right, '132' naive, '213' absorb-
    recompute, 'ru' absorb-reuse (precomputed)."""
    _, H, Q, K, dn, dr, _ = _dims(cfg, rope)
    B, L = batch, cache_len
    if order == "123":
        return 2 * (B * Q * H * dn + B * H * dn * K + B * H * K * L)
    if order == "132":
        return 2 * (B * Q * H * dn + B * L * K * H * dn + B * H * dn * L)
    if order == "213":
        return 2 * (H * Q * dn * K + B * H * Q * K + B * H * K * L)
    if order == "ru":
        return 2 * (B * H * Q * K + B * H * K * L)
    raise ValueError(order)


def softmax_flops(n_heads: int, cache_len: int, batch: int = 1,
                  ops_per_elem: int = 5) -> float:
    """max, sub, exp, sum, div — ~5 vector ops per score element."""
    return ops_per_elem * batch * n_heads * cache_len
