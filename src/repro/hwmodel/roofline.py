"""Roofline throughput / latency / energy model — paper Figs 4, 5, 6.

Also hosts the three-term roofline used for the TPU dry-run report:

    t_compute    = HLO_FLOPs   / (chips * peak)
    t_memory     = HLO_bytes   / (chips * hbm_bw)
    t_collective = coll_bytes  / (chips * ici_bw)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.mla import MLAConfig
from ..core.schemes import PlatformPoint
from . import attention_costs as ac
from .attention_costs import Cost
from .platforms import EnergyModel


def attainable_time(cost: Cost, platform: PlatformPoint) -> float:
    """Single-chip two-term roofline latency (s)."""
    return max(cost.flops / platform.peak_flops, cost.bytes / platform.hbm_bw)


def throughput(cost: Cost, platform: PlatformPoint) -> float:
    """Layers (or steps) per second."""
    return 1.0 / attainable_time(cost, platform)


def energy_pj(cost: Cost, em: EnergyModel) -> float:
    return em.energy_pj(cost.flops, cost.bytes)


def decode_cost(method: str, *, cache_len: int, batch: int = 1,
                mla_cfg: Optional[MLAConfig] = None,
                dtype_bytes: int = 2, rope: bool = False,
                with_softmax: bool = True) -> Cost:
    """Uniform access to the paper's four methods (+ 'mla_seq', 'mla_naive')."""
    mla_cfg = mla_cfg or ac.DSV3_MLA
    if method.startswith("mla_"):
        c = ac.mla_decode_cost(mla_cfg, scheme=method[4:], cache_len=cache_len,
                               batch=batch, dtype_bytes=dtype_bytes, rope=rope)
        n_h = mla_cfg.n_heads
    elif method == "mha_l":
        c = ac.mha_decode_cost(ac.MHA_L, cache_len=cache_len, batch=batch,
                               dtype_bytes=dtype_bytes)
        n_h = ac.MHA_L.n_heads
    elif method == "mha_s":
        c = ac.mha_decode_cost(ac.MHA_S, cache_len=cache_len, batch=batch,
                               dtype_bytes=dtype_bytes)
        n_h = ac.MHA_S.n_heads
    else:
        raise ValueError(method)
    if with_softmax:
        sm = ac.softmax_flops(n_h, cache_len, batch)
        c = Cost(c.flops + sm, c.bytes, {**c.breakdown, "softmax": sm})
    return c


def prefill_cost(method: str, *, seq_len: int, batch: int = 1,
                 mla_cfg: Optional[MLAConfig] = None, dtype_bytes: int = 2,
                 rope: bool = False) -> Cost:
    mla_cfg = mla_cfg or ac.DSV3_MLA
    if method.startswith("mla"):
        return ac.mla_prefill_cost(mla_cfg, seq_len=seq_len, batch=batch,
                                   dtype_bytes=dtype_bytes, rope=rope)
    cfg = ac.MHA_L if method == "mha_l" else ac.MHA_S
    return ac.mha_prefill_cost(cfg, seq_len=seq_len, batch=batch,
                               dtype_bytes=dtype_bytes)


# --------------------------------------------------- three-term (TPU) ------


@dataclasses.dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:  # perfectly-overlapped lower bound
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term time that is compute: how close the
        program is to being compute-bound at the roofline."""
        return self.t_compute / max(self.t_total, 1e-30)


def three_term(hlo_flops: float, hlo_bytes: float, coll_bytes: float,
               chips: int, peak: float, hbm_bw: float, ici_bw: float) -> RooflineTerms:
    """All inputs are *global* (whole-program) quantities; per-chip division
    happens here.  coll_bytes should already be summed over HLO collectives
    (per-chip shard sizes), so it is divided by ici_bw only."""
    return RooflineTerms(
        t_compute=hlo_flops / (chips * peak),
        t_memory=hlo_bytes / (chips * hbm_bw),
        t_collective=coll_bytes / ici_bw,
    )
