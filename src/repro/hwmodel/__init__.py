from . import attention_costs, platforms, roofline
from .attention_costs import Cost, DSV3_MLA, MHA_L, MHA_S, MHAConfig
from .platforms import PLATFORMS, EnergyModel
from .roofline import RooflineTerms, three_term

__all__ = [
    "attention_costs", "platforms", "roofline",
    "Cost", "DSV3_MLA", "MHA_L", "MHA_S", "MHAConfig",
    "PLATFORMS", "EnergyModel", "RooflineTerms", "three_term",
]
