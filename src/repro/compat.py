"""Version-compat shims for JAX API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg ``check_rep`` -> ``check_vma`` along
the way.  Callers in this repo use the NEW spelling (``jax.shard_map``-style,
``check_vma=``); this module resolves it against whatever the installed JAX
provides so the same source runs on both sides of the migration.
"""
from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6: new API
    shard_map = jax.shard_map
else:                                              # older jax: experimental
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    @functools.wraps(_legacy_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        # the legacy kwarg is ``check_rep``; same meaning.
        kw.setdefault("check_rep", check_vma)
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)


def cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older jaxlibs return a one-element list of dicts (one per executable
    module); newer ones return the dict directly.  Either way, hand back a
    plain dict ({} when the backend reports nothing).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
