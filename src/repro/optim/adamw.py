"""AdamW optimizer as pure pytree functions (no optax dependency).

State is a dict {step, mu, nu}; ``mu``/``nu`` mirror the param tree so the
whole optimizer state inherits the params' PartitionSpecs (FSDP-sharded
optimizer state = ZeRO).  fp32 master moments regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # Moment dtype. bf16 moments halve optimizer HBM (398B-scale models do
    # not fit fp32 moments on a 16 GB v5e even 512-way sharded — DESIGN §7);
    # update math still runs in fp32.
    moment_dtype: Any = jnp.float32


def init(params, cfg: Optional["AdamWConfig"] = None) -> dict:
    md = cfg.moment_dtype if cfg is not None else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.float32(cfg.lr)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        md = mu.dtype
        mu = (b1 * mu.astype(jnp.float32) + (1 - b1) * g)
        nu = (b2 * nu.astype(jnp.float32) + (1 - b2) * g * g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu.astype(md), nu.astype(md))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
