"""Error-feedback top-k gradient compression (distributed-optimization trick
for slow inter-pod links; DESIGN.md §7).

Standard EF-SGD/EF21 shape: each step, add the carried error to the fresh
gradient, transmit only the top-k fraction of entries (by magnitude), and
carry the residual.  On a real multi-pod deployment the sparse tensor is
what crosses the slow pod-to-pod links (the dense all-reduce still runs
over fast intra-pod ICI); here the compression operator itself is exact and
unit-tested, and the transport saving is accounted analytically
(``compression_ratio`` bytes) in the roofline report.

The operator is applied per-leaf with a *per-leaf* k, keeps the mask dense
(TPU-friendly: top-k via threshold on |g|, no scatter), and is fully
deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TopKConfig:
    fraction: float = 0.01          # keep top 1% of entries per leaf
    min_elems: int = 1024           # leaves smaller than this pass through


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g, err, fraction: float, min_elems: int):
    g = g.astype(jnp.float32) + err
    n = g.size
    if n < min_elems:
        return g, jnp.zeros_like(g)
    k = max(1, int(n * fraction))
    flat = jnp.abs(g.reshape(-1))
    # threshold = k-th largest magnitude; jax.lax.top_k on |g| (exact)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
    sent = g * mask
    return sent, g - sent


def compress(cfg: TopKConfig, grads, error):
    """Returns (sparse_grads, new_error). sparse + error == grads + error_in."""
    out = jax.tree.map(
        lambda g, e: _compress_leaf(g, e, cfg.fraction, cfg.min_elems),
        grads, error)
    sent = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return sent, err


def compression_ratio(cfg: TopKConfig, params) -> float:
    """Effective bytes ratio of the compressed all-reduce: top-k as
    (value+index) pairs = k * 8 bytes vs n * 4 bytes dense."""
    total_n, total_sent = 0, 0.0
    for p in jax.tree.leaves(params):
        n = p.size
        total_n += n
        total_sent += n if n < cfg.min_elems else max(1, int(n * cfg.fraction)) * 2
    return total_sent / max(total_n, 1)
