from .adamw import AdamWConfig, init as adamw_init, update as adamw_update, \
    clip_by_global_norm, global_norm
from .schedules import constant, cosine, linear_warmup
from .compression import TopKConfig, compress, compression_ratio, init_error
